"""Fault injection + supervisor (DESIGN.md §10): deterministic chaos
plans, bounded alloc retries, NaN quarantine, host-page checksums,
watchdog recovery of stuck lanes, disconnect bursts, the degradation
ladder, invariant checking, and seed-replay determinism.

The headline guarantees these tests pin down:

  * every completed request under chaos is byte-identical to its
    fault-free twin (refresh_interval=1 makes outputs a pure function
    of the canvas, so preemption/quarantine/fallback never shift bits);
  * aborted requests drain to zero held pages across BOTH tiers;
  * the engine never deadlocks — stalls resolve within the watchdog's
    virtual-clock budget, alloc backoff aborts past its retry budget;
  * the same seed replays the same fault sites, aborts the same uids
    and leaves the same survivor bytes, run after run.
"""
import numpy as np
import pytest

from repro.core.strategy import SPACache
from repro.dlm.session import DecodeSession
from repro.serving.engine import ServingEngine
from repro.serving.faults import (FAULT_SITES, FaultInjector, FaultPlan,
                                  choose_index)
from repro.serving.hier import HostPageCorruption
from repro.serving.supervisor import (EngineSupervisor, InvariantViolation,
                                      SupervisorConfig)

PAGE = 4
CANVAS = 16
N_LOG = CANVAS // PAGE


def _strat():
    # refresh_interval=1: the cache is rebuilt from the canvas every
    # step, so outputs depend ONLY on prompt+committed tokens — chaos
    # reordering (preemption, quarantine, cold fallback) is bit-safe
    return SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                    refresh_interval=1)


def _engine(cfg, params, *, fault_plan=None, sup_cfg=None, max_batch=2,
            pool_pages=13, host_pages=0, prefix_cache=True,
            supervise=True):
    return ServingEngine(
        cfg, params, max_batch=max_batch, canvas_len=CANVAS,
        strategy=_strat(), pool_pages=pool_pages, page_size=PAGE,
        prefix_cache=prefix_cache, host_pages=host_pages,
        host_dtype="f32", fault_plan=fault_plan, supervise=supervise,
        supervisor_cfg=sup_cfg)


def _prompts(cfg, n, lens=8, seed=11):
    rng = np.random.default_rng(seed)
    if isinstance(lens, int):
        lens = [lens] * n
    return [rng.integers(0, cfg.vocab_size - 1, ln).astype(np.int32)
            for ln in lens[:n]]


def _outputs(eng):
    return {r.uid: (None if r.output is None
                    else np.asarray(r.output).tobytes())
            for r in eng.done}


# ---------------------------------------------------------------------------
# FaultPlan / FaultInjector units
# ---------------------------------------------------------------------------

def test_fault_plan_validation_and_probe_determinism():
    with pytest.raises(ValueError):
        FaultPlan(rates={"bogus_site": 0.5})
    plan = FaultPlan(seed=3, at={"pool_alloc": (1, 4)},
                     rates={"step_nan": 0.5},
                     max_fires={"step_nan": 2})
    a, b = FaultInjector(plan), FaultInjector(plan)
    for inj in (a, b):
        hits = [inj.fire("pool_alloc") for _ in range(6)]
        assert [i for i, h in enumerate(hits) if h] == [1, 4]
        for _ in range(64):
            inj.fire("step_nan")
        assert inj.fired["step_nan"] == 2        # max_fires caps the storm
    assert a.log == b.log                        # the replay fingerprint
    assert a.total_fired == b.total_fired == 4
    # sticky stalls: once fired, stalled until cleared
    plan2 = FaultPlan(at={"lane_stall": (0,)})
    inj = FaultInjector(plan2)
    lane = object()
    assert inj.stall_lane(lane)
    assert inj.stall_lane(lane)                  # sticky, no new probe
    assert inj.fired["lane_stall"] == 1
    inj.clear_stall(lane)
    assert not inj.stall_lane(lane)
    # deterministic victim choice, in range
    picks = [choose_index(3, "nan_row", k, 4) for k in range(8)]
    assert picks == [choose_index(3, "nan_row", k, 4) for k in range(8)]
    assert all(0 <= p < 4 for p in picks)


def test_corrupt_array_flips_bits():
    inj = FaultInjector(FaultPlan())
    x = np.ones((4, 4), np.float32)
    y = x.copy()
    inj.corrupt_array(y)
    assert not np.array_equal(x, y)


# ---------------------------------------------------------------------------
# pool_alloc: transient failure retries; hard failure aborts bounded
# ---------------------------------------------------------------------------

def test_alloc_fault_transient_retry_completes(tiny_cfg, tiny_params):
    prompts = _prompts(tiny_cfg, 3)
    base = _engine(tiny_cfg, tiny_params)
    for p in prompts:
        base.submit(p, gen_len=8)
    base.run()
    want = _outputs(base)

    eng = _engine(tiny_cfg, tiny_params,
                  fault_plan=FaultPlan(at={"pool_alloc": (0,)}))
    for p in prompts:
        eng.submit(p, gen_len=8)
    eng.run()
    assert eng.stats.alloc_faults == 1
    assert eng.stats.requests_faulted == 0
    assert eng.stats.requests_done == 3
    assert _outputs(eng) == want                 # retry is invisible
    assert eng.pool.used == eng.prefix.held_pages


def test_alloc_fault_hard_aborts_past_retry_budget(tiny_cfg, tiny_params):
    events = []
    eng = _engine(tiny_cfg, tiny_params,
                  fault_plan=FaultPlan(rates={"pool_alloc": 1.0}),
                  sup_cfg=SupervisorConfig(max_alloc_retries=2))
    for p in _prompts(tiny_cfg, 2):
        eng.submit(p, gen_len=8, stream=True, sink=events.append)
    eng.run()                                    # must terminate
    assert eng.stats.requests_faulted == 2
    assert eng.stats.requests_done == 0
    assert all(r.fault == "pool_alloc" for r in eng.done)
    assert [ev.kind for ev in events] == ["aborted", "aborted"]
    assert eng.stats.alloc_faults == 2 * 3       # initial try + 2 retries
    assert eng.pool.used == eng.prefix.held_pages == 0
    assert not eng.pool.refcounts


# ---------------------------------------------------------------------------
# step_nan: quarantine only the poisoned request, requeue lane-mates
# ---------------------------------------------------------------------------

def test_nan_quarantine_aborts_only_poisoned_row(tiny_cfg, tiny_params):
    # k_schedule rounds the refresh budget UP to a multiple of 16, so a
    # 16-token canvas refreshes EVERY row each step and poisoned pages
    # are overwritten before anything reads them.  A 32-token canvas
    # keeps k=16 < N: half the rows read stale (poisoned) cache each
    # step, so the NaN must surface in the hidden states.
    canvas = 2 * CANVAS

    def mk(fault_plan=None):
        return ServingEngine(
            tiny_cfg, tiny_params, max_batch=2, canvas_len=canvas,
            strategy=_strat(), pool_pages=2 * (canvas // PAGE) + 1,
            page_size=PAGE, prefix_cache=False, fault_plan=fault_plan,
            supervise=True)

    prompts = [np.asarray([1, 2, 3, 4], np.int32),
               np.asarray([9, 8, 7, 6], np.int32)]
    base = mk()
    for p in prompts:
        base.submit(p, gen_len=canvas - 4)
    base.run()
    want = _outputs(base)

    eng = mk(FaultPlan(at={"step_nan": (2,)}))
    for p in prompts:
        eng.submit(p, gen_len=canvas - 4)
    eng.run()
    assert eng.stats.requests_faulted == 1
    assert eng.stats.requests_done == 1
    assert eng.stats.nan_quarantines >= 1
    faulted = [r for r in eng.done if r.fault == "nan"]
    survivor = [r for r in eng.done if r.fault is None]
    assert len(faulted) == 1 and faulted[0].output is None
    assert len(survivor) == 1
    # the lane-mate was requeued via a preemption snapshot and its
    # output is byte-identical to the fault-free twin
    assert survivor[0].preemptions >= 1
    assert _outputs(eng)[survivor[0].uid] == want[survivor[0].uid]
    assert eng.pool.used == 0 and not eng.pool.refcounts


# ---------------------------------------------------------------------------
# host tier: store refusal degrades, corruption falls back cold
# ---------------------------------------------------------------------------

def _pressure_cycle(eng, cfg):
    """cold(p0) -> pool-pressure eviction of p0's entry (demote) ->
    warm(p0) (promote).  Returns (cold_output, warm_output)."""
    prompts = _prompts(cfg, 3, seed=0)
    u = eng.submit(prompts[0], gen_len=8)
    eng.run()
    cold = next(r for r in eng.done if r.uid == u).output
    for p in prompts[1:]:
        eng.submit(p, gen_len=8)
    eng.run()
    u = eng.submit(prompts[0], gen_len=8)
    eng.run()
    warm = next(r for r in eng.done if r.uid == u).output
    return cold, warm


def test_host_store_fault_drops_demotion(tiny_cfg, tiny_params):
    eng = _engine(tiny_cfg, tiny_params, pool_pages=9, host_pages=16,
                  fault_plan=FaultPlan(at={"host_store": (0,)}))
    cold, warm = _pressure_cycle(eng, tiny_cfg)
    assert eng.tier.store_faults == 1
    # the refused demotion dropped its entry instead (the §9 graceful
    # path): an 8-token-prompt entry spans 2 pages.  Which entry the
    # fault hits depends on eviction order, so later demotions may
    # still succeed — the guarantee is graceful accounting, and that
    # the warm request decodes identically either way (promotion is
    # bit-exact, cold fallback re-prefills).
    assert eng.stats.prefix_dropped_pages >= 2
    np.testing.assert_array_equal(cold, warm)
    assert eng.host_pool.used_pages == eng.prefix.host_held_pages


def test_host_corruption_checksum_cold_fallback(tiny_cfg, tiny_params):
    eng = _engine(tiny_cfg, tiny_params, pool_pages=9, host_pages=16,
                  fault_plan=FaultPlan(at={"host_corrupt": (0,)}))
    cold, warm = _pressure_cycle(eng, tiny_cfg)
    assert eng.stats.host_checksum_failures >= 1
    assert eng.stats.cold_prefill_fallbacks >= 1
    assert eng.tier.checksum_failures >= 1
    # corrupt bytes never reached the device: the warm request was
    # served by a cold prefill, byte-identical to the cold run
    np.testing.assert_array_equal(cold, warm)
    # the corrupted entry's host slots were freed, trie refs scrubbed
    assert eng.host_pool.used_pages == eng.prefix.host_held_pages
    eng.drop_prefix_cache()
    assert eng.pool.used == 0 and eng.host_pool.used_pages == 0


def test_tier_checksum_unit_detects_bitflip():
    """TierManager-level: a bit-flipped host slot fails checksum on
    promotion, the WHOLE entry's slots are freed (a partial promotion
    can never serve the hit), and no partial data escapes."""
    from repro.serving.hier import HostPagePool, TierManager

    rng = np.random.default_rng(0)
    data = rng.normal(size=(2, 16, PAGE, 6)).astype(np.float32)

    def read(sig, pages):
        return {"kv": {"k": data[:, pages], "v": 2.0 * data[:, pages]}}

    tier = TierManager(HostPagePool(8), host_dtype="f32",
                       read_pages=read)
    sig = (16, True, True, "f32")
    tier.note_published(sig, [1, 2], None)
    refs = tier.demote([1, 2])
    assert refs is not None and len(refs) == 2
    assert all(r.checksum != 0 for r in refs)
    tier.host.corrupt_slot(refs[0].sig, refs[0].repr_, refs[0].slot)
    with pytest.raises(HostPageCorruption):
        tier.promote(list(refs))
    assert tier.checksum_failures == 1
    assert tier.host.used_pages == 0             # nothing left resident
    assert tier.host.used_units == 0


# ---------------------------------------------------------------------------
# lane_stall: the watchdog bounds stuck-lane latency
# ---------------------------------------------------------------------------

def test_watchdog_recovers_stuck_lane(tiny_cfg, tiny_params):
    prompts = _prompts(tiny_cfg, 2)
    base = _engine(tiny_cfg, tiny_params, prefix_cache=False)
    for p in prompts:
        base.submit(p, gen_len=8)
    base.run()
    want = _outputs(base)
    base_steps = base.stats.steps

    budget = 4
    eng = _engine(tiny_cfg, tiny_params, prefix_cache=False,
                  fault_plan=FaultPlan(at={"lane_stall": (0,)}),
                  sup_cfg=SupervisorConfig(watchdog_budget=budget))
    for p in prompts:
        eng.submit(p, gen_len=8)
    eng.run()
    assert eng.stats.watchdog_fires == 1
    assert eng.stats.preemptions >= 2            # whole lane force-preempted
    assert eng.stats.requests_done == 2
    assert _outputs(eng) == want                 # resume semantics: bit-equal
    # no deadlock, and the stall cost is bounded by the virtual-clock
    # budget (stalled iterations + the re-run after recovery)
    assert eng.stats.steps <= 2 * base_steps + budget + 2
    assert eng.pool.used == 0


# ---------------------------------------------------------------------------
# disconnect: a burst cancels streaming requests only
# ---------------------------------------------------------------------------

def test_disconnect_burst_cancels_streaming_only(tiny_cfg, tiny_params):
    prompts = _prompts(tiny_cfg, 2)
    base = _engine(tiny_cfg, tiny_params, prefix_cache=False)
    for p in prompts:
        base.submit(p, gen_len=8)
    base.run()
    want = _outputs(base)

    events = []
    eng = _engine(tiny_cfg, tiny_params, prefix_cache=False,
                  fault_plan=FaultPlan(at={"disconnect": (1,)}))
    u_stream = eng.submit(prompts[0], gen_len=8, stream=True,
                          sink=events.append)
    u_plain = eng.submit(prompts[1], gen_len=8)
    eng.run()
    assert eng.stats.disconnect_bursts == 1
    assert eng.stats.requests_canceled == 1
    assert eng.stats.requests_done == 1
    by_uid = {r.uid: r for r in eng.done}
    assert by_uid[u_stream].canceled and by_uid[u_stream].output is None
    assert events[-1].kind == "canceled"
    assert _outputs(eng)[u_plain] == want[u_plain]
    assert eng.pool.used == 0


# ---------------------------------------------------------------------------
# degradation ladder: up under pressure, down when it clears
# ---------------------------------------------------------------------------

def test_degradation_ladder_up_and_down(tiny_cfg, tiny_params):
    eng = _engine(tiny_cfg, tiny_params, host_pages=16,
                  sup_cfg=SupervisorConfig(pressure_window=4,
                                           escalate_at=2, cooldown=2,
                                           shed_below=1,
                                           hopeless_margin=0.5))
    sup = eng.supervisor
    assert isinstance(sup, EngineSupervisor)

    def tick(n=1, pressure=0):
        for _ in range(n):
            eng.stats.steps += 1
            for _ in range(pressure):
                sup.note_pressure("test")
            sup.on_iteration()

    tick(3, pressure=1)                          # sustained pressure
    assert sup.level >= 1 and eng._publish_paused
    tick(3, pressure=1)
    tick(3, pressure=1)
    assert sup.level == 3
    assert eng._host_tier_paused and eng.prefix.demote_paused
    assert eng._shed_low_priority and eng._shed_below == 1
    assert eng._hopeless_margin == 0.5
    ups = [lvl for _, lvl in eng.stats.degradation_events]
    assert ups == [1, 2, 3]
    # pressure clears: one rung per quiet cooldown window, back to L0
    tick(40)
    assert sup.level == 0
    assert eng.stats.degrade_level == 0
    assert not eng._publish_paused and not eng._host_tier_paused
    assert not eng.prefix.demote_paused
    assert not eng._shed_low_priority and eng._hopeless_margin == 0.0
    levels = [lvl for _, lvl in eng.stats.degradation_events]
    assert levels == [1, 2, 3, 2, 1, 0]          # up AND down, stepwise
    assert eng.stats.degradations == 3 and eng.stats.restorations == 3


def test_ladder_l3_sheds_low_priority_queued(tiny_cfg, tiny_params):
    eng = _engine(tiny_cfg, tiny_params,
                  sup_cfg=SupervisorConfig(shed_below=1))
    sup = eng.supervisor
    sup._set_level(3, step=0)
    lo = eng.submit(_prompts(tiny_cfg, 1)[0], gen_len=8, priority=0)
    hi = eng.submit(_prompts(tiny_cfg, 1, seed=5)[0], gen_len=8,
                    priority=2)
    eng.run()
    by_uid = {r.uid: r for r in eng.done}
    assert by_uid[lo].shed and by_uid[lo].output is None
    assert by_uid[hi].output is not None
    assert eng.stats.requests_shed == 1 and eng.stats.requests_done == 1


# ---------------------------------------------------------------------------
# invariant checker: deliberate corruption is caught immediately
# ---------------------------------------------------------------------------

def test_invariant_checker_catches_refcount_corruption(tiny_cfg,
                                                       tiny_params):
    eng = _engine(tiny_cfg, tiny_params)
    eng.submit(_prompts(tiny_cfg, 1)[0], gen_len=8)
    state = {"armed": True}

    def on_step(e):
        if state["armed"] and e._running:
            req = next(iter(e._running.values()))
            e.pool.retain([req.pages[0]])        # phantom reader
            state["armed"] = False

    with pytest.raises(InvariantViolation):
        eng.run(on_step=on_step)


def test_invariant_checker_passes_clean_run(tiny_cfg, tiny_params):
    eng = _engine(tiny_cfg, tiny_params, host_pages=16)
    for p in _prompts(tiny_cfg, 4, lens=[8, 8, 4, 8]):
        eng.submit(p, gen_len=8)
    eng.run()
    assert eng.stats.invariant_checks > 0
    assert eng.stats.requests_done == 4


# ---------------------------------------------------------------------------
# seed replay: the same chaos, twice — and survivors match fault-free
# ---------------------------------------------------------------------------

STORM = FaultPlan(seed=7, rates={"pool_alloc": 0.05, "step_nan": 0.03,
                                 "lane_stall": 0.02, "disconnect": 0.02,
                                 "host_store": 0.3, "host_corrupt": 0.3})


def _storm_run(cfg, params, plan):
    eng = _engine(cfg, params, host_pages=16, fault_plan=plan,
                  sup_cfg=SupervisorConfig(watchdog_budget=6))
    prompts = _prompts(cfg, 4, lens=[8, 8, 4, 8], seed=2)
    prompts.append(prompts[0].copy())            # a shared-prefix repeat
    prompts.append(prompts[1].copy())
    for i, p in enumerate(prompts):
        eng.submit(p, gen_len=8, stream=(i % 2 == 0),
                   sink=(lambda ev: None) if i % 2 == 0 else None)
    eng.run()
    return eng


def test_chaos_replay_is_deterministic(tiny_cfg, tiny_params):
    a = _storm_run(tiny_cfg, tiny_params, STORM)
    b = _storm_run(tiny_cfg, tiny_params, STORM)
    assert a.faults.total_fired > 0              # the storm actually hit
    assert a.faults.log == b.faults.log          # same sites, same probes
    aborted_a = {r.uid for r in a.done if r.fault is not None}
    assert aborted_a == {r.uid for r in b.done if r.fault is not None}
    assert _outputs(a) == _outputs(b)            # survivor bytes identical

    # survivors also match the fault-free twin exactly
    clean = _engine(tiny_cfg, tiny_params, host_pages=16)
    prompts = _prompts(tiny_cfg, 4, lens=[8, 8, 4, 8], seed=2)
    prompts.append(prompts[0].copy())
    prompts.append(prompts[1].copy())
    for p in prompts:
        clean.submit(p, gen_len=8)
    clean.run()
    want = _outputs(clean)
    for r in a.done:
        if r.fault is None and not r.canceled and not r.shed:
            assert _outputs(a)[r.uid] == want[r.uid]

    # aborted requests drained to zero held pages across BOTH tiers
    for eng in (a, b):
        assert eng.pool.used == eng.prefix.held_pages
        assert all(rc == 1 for rc in eng.pool.refcounts.values())
        assert eng.host_pool.used_pages == eng.prefix.host_held_pages
        eng.drop_prefix_cache()
        assert eng.pool.used == 0 and eng.host_pool.used_pages == 0


def test_survivors_match_dense_reference_both_run_modes(tiny_cfg,
                                                        tiny_params):
    """A full-length chaos survivor decodes to the same bytes as a
    dense reference session — through BOTH the host step loop and the
    device-resident compiled loop."""
    prompt = np.asarray([3, 1, 4, 1], np.int32)
    eng = _engine(tiny_cfg, tiny_params, prefix_cache=False,
                  fault_plan=FaultPlan(at={"lane_stall": (0,)}),
                  sup_cfg=SupervisorConfig(watchdog_budget=3))
    u = eng.submit(prompt, gen_len=12)           # prompt+gen == canvas
    eng.run()
    served = next(r for r in eng.done if r.uid == u).output
    assert served is not None

    sess = DecodeSession(tiny_params, tiny_cfg, strategy=_strat())
    sess.prefill(prompt[None], gen_len=12)
    host_toks, _ = sess.run()
    sess2 = DecodeSession(tiny_params, tiny_cfg, strategy=_strat())
    sess2.prefill(prompt[None], gen_len=12)
    dev_toks, _ = sess2.run_compiled()
    ref_host = np.asarray(host_toks)[0, 4:]
    ref_dev = np.asarray(dev_toks)[0, 4:]
    np.testing.assert_array_equal(ref_host, ref_dev)
    np.testing.assert_array_equal(np.asarray(served), ref_host)

"""SSD chunk Pallas kernel vs sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ssd_chunk import ssd_chunk_scan


@pytest.mark.parametrize("t,hd,ds,chunk", [
    (64, 16, 8, 16), (128, 32, 16, 32), (96, 8, 4, 96),
])
def test_ssd_chunk_matches_sequential(t, hd, ds, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (t, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (t,)))
    a = -jnp.exp(jax.random.normal(ks[2], ()) * 0.2)
    steps = dt * a                               # log-decay per step
    b = jax.random.normal(ks[3], (t, ds))
    c = jax.random.normal(ks[4], (t, ds))

    # in-chunk cumulative log-decay (resets each chunk)
    la = steps.reshape(t // chunk, chunk)
    la = jnp.cumsum(la, axis=1).reshape(t)

    y = ssd_chunk_scan(x, dt, la, b, c, chunk=chunk, interpret=True)
    y_ref = ref.ssd_chunk_ref(x, dt, steps, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-4)


def test_ssd_chunk_bf16():
    t, hd, ds, chunk = 64, 16, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (t, hd), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (t,)))
    a = -jnp.exp(jax.random.normal(ks[2], ()) * 0.2)
    steps = dt * a
    b = jax.random.normal(ks[3], (t, ds))
    c = jax.random.normal(ks[4], (t, ds))
    la = jnp.cumsum(steps.reshape(-1, chunk), axis=1).reshape(t)
    y = ssd_chunk_scan(x, dt, la, b, c, chunk=chunk, interpret=True)
    y_ref = ref.ssd_chunk_ref(x, dt, steps, b, c)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=5e-2, atol=5e-2)

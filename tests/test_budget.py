"""Adaptive budget allocation (paper Eq. 5) — unit + property tests."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import SPAConfig
from repro.core import budget


def spa(rho_p=0.25, rho_1=0.03, rho_L=0.13, lp=None, schedule="adaptive"):
    return SPAConfig(schedule=schedule, rho_peak=rho_p, rho_first=rho_1,
                     rho_last=rho_L, layer_peak=lp)


def test_peak_at_lp():
    s = spa(lp=24)
    rhos = budget.rho_schedule(s, 32)
    assert np.argmax(rhos) == 23          # 1-indexed l_p = 24
    assert rhos[23] == pytest.approx(0.25)


def test_boundary_values_match_eq5():
    s = spa(lp=24)
    rhos = budget.rho_schedule(s, 32)
    assert rhos[0] == pytest.approx(0.03, rel=1e-6)    # rho_1 at l=1
    assert rhos[31] == pytest.approx(0.13, rel=1e-6)   # rho_L at l=L


def test_uniform_schedule():
    rhos = budget.rho_schedule(spa(schedule="uniform"), 16)
    assert np.allclose(rhos, 0.25)


def test_paper_table6_llada():
    """LLaDA-8B hyperparameters (Appendix C Table 6): avg rho ~16% at
    rho_p=25% (paper Table 4 reports a-bar = 16%)."""
    s = SPAConfig(rho_peak=0.25, rho_first=0.03, rho_last=0.13,
                  layer_peak=24)
    avg = budget.average_rho(s, 32)
    assert 0.10 < avg < 0.20


@given(st.integers(2, 96), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_bucketize_never_underallocates(n_layers, n_buckets):
    s = spa(lp=max(1, int(0.6 * n_layers)))
    ks = budget.k_schedule(s, n_layers, 1024)
    segs = budget.bucketize(ks, n_buckets)
    # contiguous, ordered cover
    assert segs[0][0] == 0 and segs[-1][1] == n_layers
    for (a0, b0, _), (a1, _, _) in zip(segs, segs[1:]):
        assert b0 == a1
    # never under-allocate
    for a, b, kseg in segs:
        assert kseg == max(ks[a:b])
        for l in range(a, b):
            assert kseg >= ks[l]
    assert budget.over_provision_ratio(ks, segs) >= 1.0


@given(st.floats(0.05, 0.9), st.integers(4, 64), st.integers(64, 4096))
@settings(max_examples=30, deadline=None)
def test_k_schedule_bounds(rho_p, n_layers, seq_len):
    s = spa(rho_p=rho_p, rho_1=rho_p / 8, rho_L=rho_p / 2)
    ks = budget.k_schedule(s, n_layers, seq_len)
    # k rounds UP to a multiple of 16 for shardability (never under)
    assert all(1 <= k <= min(seq_len, math.ceil(rho_p * seq_len) + 16)
               for k in ks)
    assert all(k % 16 == 0 or k == seq_len or seq_len < 16 for k in ks)


def test_more_buckets_less_waste():
    s = spa(lp=24)
    ks = budget.k_schedule(s, 32, 4096)
    waste = [budget.over_provision_ratio(ks, budget.bucketize(ks, nb))
             for nb in (1, 2, 4, 8, 16)]
    assert all(w1 >= w2 - 1e-9 for w1, w2 in zip(waste, waste[1:]))

"""SLO-aware scheduling (DESIGN.md §8): policy unit behaviour, engine
shed/boost integration, goodput accounting, virtual-clock injection."""
import math

import numpy as np
import pytest

from repro.core.strategy import SPACache
from repro.serving.engine import ServingEngine
from repro.serving.slo import SLO, SLOPolicy, StepClock

PAGE, CANVAS = 4, 16


class _R:
    """Duck-typed request for policy unit tests."""

    def __init__(self, priority=0, slo=None, submitted_at=0.0,
                 first_token_at=None):
        self.priority = priority
        self.slo = slo
        self.submitted_at = submitted_at
        self.first_token_at = first_token_at


def test_slo_met_bounds():
    slo = SLO(ttft=2.0, deadline=10.0)
    assert slo.met(ttft=2.0, e2e=10.0)
    assert not slo.met(ttft=2.1, e2e=5.0)
    assert not slo.met(ttft=1.0, e2e=10.1)
    assert SLO().met(ttft=1e9, e2e=1e9)      # unbounded default


def test_policy_urgency_boost_and_slack():
    pol = SLOPolicy(boost=2, urgency_frac=0.5)
    r = _R(priority=1, slo=SLO(ttft=10.0), submitted_at=0.0)
    assert pol.ttft_slack(r, now=3.0) == pytest.approx(7.0)
    assert not pol.urgent(r, now=3.0)         # slack 7 >= 0.5*10
    assert pol.effective_priority(r, now=3.0) == 1
    assert pol.urgent(r, now=6.0)             # slack 4 < 5
    assert pol.effective_priority(r, now=6.0) == 3
    # TTFT already delivered -> no longer urgent, infinite slack
    r.first_token_at = 2.0
    assert pol.ttft_slack(r, now=9.0) == math.inf
    assert pol.effective_priority(r, now=9.0) == 1
    # no SLO -> never urgent
    assert pol.effective_priority(_R(priority=4), now=100.0) == 4


def test_policy_hopeless():
    pol = SLOPolicy()
    r = _R(slo=SLO(ttft=5.0, deadline=20.0), submitted_at=0.0)
    assert not pol.hopeless(r, now=4.0)
    assert pol.hopeless(r, now=5.5)           # TTFT missed in queue
    started = _R(slo=SLO(ttft=5.0, deadline=20.0), first_token_at=3.0)
    assert not pol.hopeless(started, now=15.0)
    assert pol.hopeless(started, now=21.0)    # e2e deadline passed
    assert not pol.hopeless(_R(), now=1e9)    # no SLO: never hopeless


def test_step_clock():
    clock = StepClock(tick=2.0)
    assert clock() == 0.0
    clock.advance()
    clock.advance(0.5)
    assert clock() == pytest.approx(2.5)


def _engine(cfg, params, **kw):
    return ServingEngine(
        cfg, params, max_batch=2, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=2 * (CANVAS // PAGE) + 1, page_size=PAGE, **kw)


def test_engine_sheds_hopeless_request(tiny_cfg, tiny_params):
    """A queued request whose TTFT deadline passes before it can start
    is shed — finalized with no output, pages intact — instead of being
    served for zero goodput."""
    rng = np.random.default_rng(0)
    blockers = [rng.integers(0, tiny_cfg.vocab_size - 1, 4)
                .astype(np.int32) for _ in range(2)]
    late = rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)

    def serve(policy):
        clock = StepClock()
        eng = _engine(tiny_cfg, tiny_params, slo_policy=policy,
                      clock=clock)
        for p in blockers:
            # occupy both slots ~12 steps, at a priority the urgency
            # boost cannot preempt — the late arrival is truly hopeless
            eng.submit(p, gen_len=12, priority=5)
        doomed = {"uid": None}

        def on_step(e):
            clock.advance()
            if doomed["uid"] is None and e.stats.steps >= 1:
                # arrives while the batch is full; TTFT expires at t=4,
                # long before a slot frees
                doomed["uid"] = e.submit(late, gen_len=4,
                                         slo=SLO(ttft=3.0))
        stats = eng.run(on_step=on_step)
        return eng, stats, doomed["uid"]

    eng, stats, doomed = serve(SLOPolicy())
    assert stats.requests_shed == 1
    assert stats.requests_done == 2
    shed = next(r for r in eng.done if r.uid == doomed)
    assert shed.shed and shed.output is None
    assert stats.slo_missed >= 1
    assert eng.pool.used == 0                 # shed request leaked nothing
    # same workload without a policy: the doomed request is served
    # anyway (and misses), burning steps the policy saved
    eng2, stats2, _ = serve(None)
    assert stats2.requests_done == 3
    assert stats2.slo_missed == 1
    assert stats2.steps > stats.steps


def test_engine_urgency_boost_reorders_queue(tiny_cfg, tiny_params):
    """EDF + urgency boost: with one free slot and two queued requests,
    the near-deadline one is admitted first even though it arrived
    last; FIFO admission would serve the slack-free one late."""
    clock = StepClock()
    eng = _engine(tiny_cfg, tiny_params,
                  slo_policy=SLOPolicy(boost=2, urgency_frac=0.6),
                  clock=clock)
    rng = np.random.default_rng(1)
    pr = rng.integers(0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)
    # blockers run ~12 steps: the urgent arrival's boost must preempt
    # one (slots AND pages are exhausted) rather than wait for a slot
    blockers = [eng.submit(pr, gen_len=12) for _ in range(2)]
    uids = {}

    def on_step(e):
        clock.advance()
        if "relaxed" not in uids:                 # arrives first...
            uids["relaxed"] = e.submit(pr, gen_len=4,
                                       slo=SLO(ttft=100.0))
        elif "urgent" not in uids:                # ...then the tight one
            uids["urgent"] = e.submit(pr, gen_len=4,
                                      slo=SLO(ttft=12.0))

    stats = eng.run(on_step=on_step)
    assert stats.requests_done == 4
    assert stats.preemptions >= 1             # boost preempted a blocker
    by_uid = {r.uid: r for r in eng.done}
    assert by_uid[uids["urgent"]].started_at \
        < by_uid[uids["relaxed"]].started_at
    assert stats.slo_met == 4


def test_goodput_and_latency_accounting(tiny_cfg, tiny_params):
    """Virtual-clock TTFT/TPOT/goodput: with one token committed per
    step and a tick of 1s, TPOT is exactly 1s and goodput counts only
    SLO-met completions."""
    clock = StepClock()
    eng = _engine(tiny_cfg, tiny_params, slo_policy=SLOPolicy(),
                  clock=clock)
    rng = np.random.default_rng(2)
    eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 4)
               .astype(np.int32), gen_len=6, slo=SLO(ttft=4.0,
                                                     deadline=20.0))
    eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 4)
               .astype(np.int32), gen_len=6)   # no SLO: trivially met
    stats = eng.run(on_step=lambda e: clock.advance())
    assert stats.requests_done == 2
    assert stats.slo_met == 2 and stats.slo_missed == 0
    assert len(stats.ttft_latencies) == 2
    assert len(stats.tpot_latencies) == 2
    pct = stats.percentiles()
    assert pct["tpot_p50"] == pytest.approx(1.0)
    assert stats.goodput(clock()) == pytest.approx(2 / clock())
    assert stats.goodput(0.0) > 0               # guards divide-by-zero

"""Optional-dependency shim for ``hypothesis``.

The property tests (`test_budget`, `test_cache`, `test_selection`,
`test_svd_proxy`) are written against the hypothesis API, but the
dependency is optional in this environment. When hypothesis is
installed it is used directly; otherwise a tiny seeded-random fallback
provides the same surface (``given``, ``settings``, ``st.integers``,
``st.floats``) so the tier-1 suite collects and runs without it.

The fallback always exercises the all-min and all-max boundary tuples
first, then ``max_examples`` seeded-random draws — deterministic across
runs, no shrinking.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample, low, high):
            self._sample = sample
            self.low = low
            self.high = high

        def sample(self, rng):
            return self._sample(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value),
                             min_value, max_value)

    st = _StrategiesModule()

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            # NOTE: no functools.wraps — pytest would introspect the
            # wrapped signature and treat the generated args as fixtures.
            def wrapper():
                n = getattr(fn, "_compat_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                fn(*[s.low for s in strats])
                fn(*[s.high for s in strats])
                for _ in range(n):
                    fn(*[s.sample(rng) for s in strats])

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

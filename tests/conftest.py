import os
import sys

# Tests run on the single real CPU device (the dry-run sets its own flags
# in a separate process). Keep XLA quiet and single-threaded-friendly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

from repro.configs import get_arch, reduced  # noqa: E402
from repro.core import runtime  # noqa: E402
from repro.models import transformer  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """The suite compiles hundreds of distinct executables (engine
    lanes x strategies x backends x run/run_compiled); keeping them
    all live eventually segfaults XLA's CPU compiler deep into the
    run.  No test shares jitted state across modules, so drop the
    caches at module boundaries (via the one shared dropper in
    repro.core.runtime — same valve bench_serving.py uses)."""
    yield
    runtime.drop_executables()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_cfg():
    return reduced(get_arch("internlm2-1.8b"), n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=128)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg, rng_key):
    return transformer.init_params(tiny_cfg, rng_key)


def make_tokens(key, cfg, batch=2, n=32):
    return jax.random.randint(key, (batch, n), 0, cfg.vocab_size - 1)

"""SPA-Cache block semantics (Algorithm 1) — exactness + update tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.configs.base import SPAConfig
from repro.core import spa_layer
from repro.core.cache import CachePolicy
from repro.dlm import decoding
from repro.models import transformer


def setup(identifier="singular", rho=1.0, arch="internlm2-1.8b",
          schedule="uniform", cache_dtype="float32", n=24):
    cfg = reduced(get_arch(arch), cache_dtype=cache_dtype)
    cfg = dataclasses.replace(cfg, spa=SPAConfig(
        identifier=identifier, rank=16, schedule=schedule, rho_peak=rho,
        rho_first=min(0.05, rho), rho_last=min(0.1, rho)))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    proxies = spa_layer.build_spa_proxies(params, cfg)
    tokens = jax.random.randint(key, (2, n), 0, cfg.vocab_size - 1)
    _, cache = decoding.prefill(params, cfg, {"tokens": tokens}, proxies)
    h0 = transformer.embed_inputs(params, cfg, {"tokens": tokens})
    return cfg, params, proxies, cache, h0


@pytest.mark.parametrize("identifier", ["singular", "value", "query",
                                        "key", "attn_in"])
def test_rho_one_equals_dense(identifier):
    """With full budget every row is refreshed -> must equal the vanilla
    forward exactly (core soundness invariant)."""
    cfg, params, proxies, cache, h0 = setup(identifier=identifier)
    h_spa, _, _ = spa_layer.spa_forward(params, cfg, cache, h0, proxies)
    h_dense, _, _ = transformer.forward_hidden(params, cfg, h0)
    np.testing.assert_allclose(np.asarray(h_spa), np.asarray(h_dense),
                               rtol=1e-4, atol=1e-4)


def test_partial_rho_bounded_divergence():
    """At rho<1 with UNCHANGED inputs, the step is a no-op approximation:
    outputs equal the cached states (selected rows recompute to the same
    values)."""
    cfg, params, proxies, cache, h0 = setup(rho=0.3)
    h_spa, new_cache, _ = spa_layer.spa_forward(params, cfg, cache, h0,
                                                proxies)
    h_dense, _, _ = transformer.forward_hidden(params, cfg, h0)
    np.testing.assert_allclose(np.asarray(h_spa), np.asarray(h_dense),
                               rtol=1e-3, atol=1e-3)


def test_cache_untouched_rows_preserved():
    cfg, params, proxies, cache, h0 = setup(rho=0.25)
    # Perturb one token's embedding strongly
    h0 = h0.at[:, 3].add(5.0)
    _, new_cache, _ = spa_layer.spa_forward(params, cfg, cache, h0,
                                            proxies)
    old_k = np.asarray(cache["attn"]["k"])
    new_k = np.asarray(new_cache["attn"]["k"])
    # at most k rows per layer changed
    n = old_k.shape[2]
    changed = (np.abs(new_k - old_k).sum(axis=(3, 4)) > 0)  # [L,B,N]
    from repro.core import budget
    ks = budget.k_schedule(cfg.spa, cfg.n_layers, n)
    for l in range(changed.shape[0]):
        assert changed[l].sum(axis=-1).max() <= ks[l]


def test_drifted_token_gets_selected():
    cfg, params, proxies, cache, h0 = setup(rho=0.2)
    h0p = h0.at[:, 5].add(10.0)   # strong drift at position 5
    from repro.core import identifiers
    x = jax.vmap(lambda hh: hh)(h0p)
    # run one spa block manually and check row 5 was refreshed in layer 0
    _, new_cache, _ = spa_layer.spa_forward(params, cfg, cache, h0p,
                                            proxies)
    old_k = np.asarray(cache["attn"]["k"][0])
    new_k = np.asarray(new_cache["attn"]["k"][0])
    assert np.abs(new_k[:, 5] - old_k[:, 5]).sum() > 0


def test_int8_cache_close_to_fp():
    cfg, params, proxies, cache, h0 = setup(rho=1.0)
    cfg8, params8, proxies8, cache8, h08 = setup(rho=1.0,
                                                 cache_dtype="int8")
    h_fp, _, _ = spa_layer.spa_forward(params, cfg, cache, h0, proxies)
    h_8, _, _ = spa_layer.spa_forward(params8, cfg8, cache8, h08,
                                      proxies8)
    # same params (same seed) -> int8 cache path stays close
    err = np.abs(np.asarray(h_fp) - np.asarray(h_8)).mean()
    scale = np.abs(np.asarray(h_fp)).mean()
    assert err < 0.1 * scale


def test_attn_out_identifier_runs():
    cfg, params, proxies, cache, h0 = setup(identifier="attn_out",
                                            rho=0.5)
    h, new_cache, _ = spa_layer.spa_forward(params, cfg, cache, h0,
                                            proxies)
    assert not bool(jnp.isnan(h).any())


def test_bucketed_scan_matches_unrolled():
    """8-layer homogeneous model: the bucketed lax.scan serve path must
    match the exact unrolled path up to bucket over-provisioning (which
    only ever refreshes MORE rows, so we compare at uniform rho where
    buckets are exact)."""
    cfg = reduced(get_arch("internlm2-1.8b"), n_layers=8)
    cfg = dataclasses.replace(cfg, spa=SPAConfig(
        identifier="singular", rank=16, schedule="uniform",
        rho_peak=0.4))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    proxies = spa_layer.build_spa_proxies(params, cfg)
    tokens = jax.random.randint(key, (2, 24), 0, cfg.vocab_size - 1)
    _, cache = decoding.prefill(params, cfg, {"tokens": tokens}, proxies)
    h0 = transformer.embed_inputs(params, cfg, {"tokens": tokens})
    h0 = h0.at[:, 2].add(1.0)

    cfg_scan = dataclasses.replace(cfg, scan_layers=True)
    cfg_unroll = dataclasses.replace(cfg, scan_layers=False)
    h_scan, cache_s, _ = spa_layer.spa_forward(params, cfg_scan, cache,
                                               h0, proxies)
    h_unroll, cache_u, _ = spa_layer.spa_forward(params, cfg_unroll,
                                                 cache, h0, proxies)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_unroll),
                               rtol=1e-4, atol=1e-4)
    for name in ("k", "v", "h", "proxy"):
        np.testing.assert_allclose(
            np.asarray(cache_s["attn"][name]),
            np.asarray(cache_u["attn"][name]), rtol=1e-4, atol=1e-4)

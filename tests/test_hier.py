"""Hierarchical cache (DESIGN.md §9): host page pool accounting, int8
round-trip fidelity bounds, stability scoring, the index demote ->
lookup -> promote handshake, and the engine-level guarantees — an
f32-demoted full hit decodes byte-identically to a cold decode for
every cached strategy in both run modes, an int8-demoted hit is
partial-hit class (states within the quantization bound, decode
completes), and the two-tier engine drains leak-free."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core import strategy as strategy_lib
from repro.core.strategy import SPACache
from repro.dlm.session import DecodeSession, SharedPrefix
from repro.serving.engine import ServingEngine
from repro.serving.hier import (HostPagePool, TierManager, page_stability)
from repro.serving.pool import PagePool, cache_signature
from repro.serving.prefix import PrefixIndex

PAGE = 4
CANVAS = 16
N_LOG = CANVAS // PAGE


def _test_instance(ident: str):
    inc = ident.endswith("+inc")
    base = ident.split("+")[0]
    cls = strategy_lib.REGISTRY[base]
    if cls is strategy_lib.SPACache:
        return SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                        incremental_ident=inc)
    if cls is strategy_lib.ValueProxyCache:
        return strategy_lib.ValueProxyCache(projection=base, rho=0.3)
    if cls is strategy_lib.WindowCache:
        return strategy_lib.WindowCache(locality_window=8, rho=0.3)
    if cls is strategy_lib.AttnOutCache:
        return strategy_lib.AttnOutCache(rho=0.5)
    return cls()


CACHED_IDENTS = sorted(i for i in strategy_lib.REGISTRY
                       if strategy_lib.REGISTRY[i].uses_cache) \
    + ["singular+inc"]


def _quant_bound(x):
    """Per-element int8 round-trip error bound: scale/2 (rounding) plus
    the f16 cast of the scale — 2^-11 relative when the scale is a
    normal f16, 2^-24 absolute in the subnormal range — times the worst
    |q| of 127."""
    amax = np.max(np.abs(np.asarray(x, np.float32)), axis=-1,
                  keepdims=True)
    scale = np.maximum(amax / 127.0, 1e-8)
    return (scale * 0.5
            + 127 * np.maximum(scale * 2.0 ** -11, 2.0 ** -24) + 1e-7)


# ---------------------------------------------------------------------------
# int8 round-trip bound (property-style, no model)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,seed", [((8, 16), 0), ((3, 4, 32), 1),
                                        ((2, 5, 4, 16), 2), ((1, 256), 3)])
def test_quantize_rows_roundtrip_bound(shape, seed):
    """Per-element reconstruction error of the host int8 representation
    is bounded by the documented ``max|row|/254`` (= scale/2) plus the
    float16 scale cast's rounding: relative 2^-11 per scale for normal
    f16 scales, absolute 2^-24 in the subnormal range (tiny rows)."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=shape)
         * 10.0 ** float(rng.integers(-3, 3))).astype(np.float32)
    q, s = cache_lib.quantize_rows_np(x)
    assert q.dtype == np.int8 and s.dtype == np.float16
    back = cache_lib.dequantize_rows_np(q, s)
    assert np.all(np.abs(x - back) <= _quant_bound(x))
    # all-zero rows round-trip to exact zeros
    z, zs = cache_lib.quantize_rows_np(np.zeros((2, 8), np.float32))
    assert np.all(cache_lib.dequantize_rows_np(z, zs) == 0.0)


# ---------------------------------------------------------------------------
# HostPagePool: half-unit accounting + double-free guard
# ---------------------------------------------------------------------------

def _blk(n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return {"kv": {"k": rng.normal(size=(2, n, PAGE, 6)).astype(dtype),
                   "v": rng.normal(size=(2, n, PAGE, 6)).astype(dtype)}}


def test_host_pool_units_and_double_free():
    host = HostPagePool(n_pages=2)               # 4 half-page units
    assert host.capacity_units == 4
    sig = ("s",)
    a = host.store(sig, "exact", 2, _blk(1))     # 2 units
    assert a is not None and host.used_units == 2
    b = host.store(sig, "int8", 1, _blk(2, 1))   # int8: half rate
    assert b is not None and host.used_units == 4
    assert host.used_pages == 3 and host.utilization == 1.0
    assert host.store(sig, "exact", 2, _blk(1, 2)) is None   # over budget
    got = host.load(sig, "exact", a)
    np.testing.assert_array_equal(got["kv"]["k"], _blk(1)["kv"]["k"])
    host.free(sig, "exact", a, 2)
    assert host.used_units == 2 and host.used_pages == 2
    with pytest.raises(AssertionError):
        host.free(sig, "exact", a, 2)            # double free of a slot
    host.free(sig, "int8", b, 1)
    assert host.used_units == 0 and host.used_pages == 0
    assert host.peak_units == 4 and host.pages_in == 3


def test_pool_free_asserts_on_shared_page(tiny_cfg):
    """Regression (DESIGN.md §5): ``PagePool.free`` is for exclusively
    owned pages — freeing a page the prefix index (or any reader) still
    holds must raise instead of silently double-releasing it into the
    free list."""
    pool = PagePool(tiny_cfg, n_pages=6, page_size=PAGE)
    pages = pool.alloc(2)
    pool.retain(pages)                           # a second holder appears
    with pytest.raises(AssertionError, match="release"):
        pool.free(pages)
    pool.release(pages)                          # drop the reader hold
    pool.free(pages)                             # now exclusive: fine
    assert pool.used == 0 and not pool.refcounts


# ---------------------------------------------------------------------------
# Stability scoring
# ---------------------------------------------------------------------------

def test_page_stability_scores():
    rng = np.random.default_rng(0)
    d = rng.normal(size=16).astype(np.float32)
    aligned = np.stack([d * s for s in (1.0, 2.0, 0.5, 3.0)])[None]
    assert page_stability(aligned) > 0.999       # parallel rows: stable
    noisy = rng.normal(size=(1, 32, 16)).astype(np.float32)
    assert page_stability(noisy) < page_stability(aligned)
    assert page_stability(np.zeros((1, 4, 16))) == 0.0
    assert page_stability(np.zeros((1, 0, 16))) == 0.0
    assert 0.0 <= page_stability(noisy) <= 1.0


# ---------------------------------------------------------------------------
# TierManager policy (fake arenas, no model)
# ---------------------------------------------------------------------------

def _fake_tier(n_host, host_dtype, n_pages=16, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(2, n_pages, PAGE, 6)).astype(np.float32)

    def read(sig, pages):
        return {"kv": {"k": data[:, pages], "v": 2.0 * data[:, pages]}}

    tier = TierManager(HostPagePool(n_host), host_dtype=host_dtype,
                       read_pages=read)
    return tier, data


def test_tier_demote_promote_exact_is_byte_identical():
    tier, data = _fake_tier(8, "f32")
    sig = (16, True, True, "f32")
    tier.note_published(sig, [1, 2], None)
    refs = tier.demote([1, 2])
    assert refs is not None and all(r.exact and r.repr_ == "exact"
                                    for r in refs)
    assert tier.host.used_units == 4 and tier.demoted_pages == 2
    out_sig, blocks = tier.promote(refs)
    assert out_sig == sig
    np.testing.assert_array_equal(blocks["kv"]["k"], data[:, [1, 2]])
    np.testing.assert_array_equal(blocks["kv"]["v"], 2.0 * data[:, [1, 2]])
    assert tier.host.used_units == 0 and tier.promoted_pages == 2


def test_tier_int8_within_bound_and_inexact():
    tier, data = _fake_tier(8, "int8")
    sig = (16, True, True, "f32")
    tier.note_published(sig, [3], None)
    refs = tier.demote([3])
    assert refs is not None and not refs[0].exact
    assert refs[0].repr_ == "int8" and refs[0].units == 1
    _, blocks = tier.promote(refs)
    orig = data[:, [3]]
    assert np.all(np.abs(blocks["kv"]["k"] - orig) <= _quant_bound(orig))


def test_tier_auto_policy_and_int8_signature():
    tier, _ = _fake_tier(8, "auto")
    sig = (16, True, True, "f32")
    stable = np.repeat(np.ones((1, 1, 8), np.float32), 4, axis=1)
    drifty = np.random.default_rng(1).normal(size=(1, 4, 8)) \
        .astype(np.float32)
    tier.note_published(sig, [1, 2], {1: stable, 2: drifty})
    assert tier.stability(1) > 0.9 > tier.stability(2)
    # auto: stable page quantizes (inexact), drifty page stays exact
    assert tier._repr_for(sig, tier.stability(1), True) == ("int8", 1, False)
    assert tier._repr_for(sig, tier.stability(2), True) == ("exact", 2, True)
    # an already-int8 device cache is bytes: exact at the cold unit rate
    sig8 = (16, True, True, "int8")
    assert tier._repr_for(sig8, 0.0, True) == ("exact", 1, True)


def test_tier_pressure_drops_stable_first():
    tier, _ = _fake_tier(1, "f32")               # 2 units: room for 1 page
    sig = (16, True, True, "f32")
    stable = np.repeat(np.ones((1, 1, 8), np.float32), 4, axis=1)
    tier.note_published(sig, [1, 2, 3], {3: stable})
    assert tier.demote([1]) is not None          # fills the tier
    assert tier.demote([2]) is None              # drift page, tier full
    assert tier.dropped_full == 1
    assert tier.demote([3]) is None              # stable page skips the
    assert tier.dropped_stable == 1              # tier under pressure
    # unknown pages (never published) always drop
    assert tier.demote([9]) is None


# ---------------------------------------------------------------------------
# PrefixIndex demote -> lookup -> promote handshake (no model)
# ---------------------------------------------------------------------------

def _toks(*vals):
    return np.asarray(vals, np.int32)


def _index_with_tier(tiny_cfg, host_pages, host_dtype="f32"):
    pool = PagePool(tiny_cfg, n_pages=32, page_size=PAGE)
    idx = PrefixIndex(PAGE)
    tier, data = _fake_tier(host_pages, host_dtype, n_pages=32)
    idx.tier = tier
    return pool, idx, tier, data


def test_index_demote_then_promote_handshake(tiny_cfg):
    pool, idx, tier, _ = _index_with_tier(tiny_cfg, 8)
    key = (CANVAS, "spec")
    prompt = _toks(*range(10))
    pages = pool.alloc(N_LOG)
    sig = (16, True, True, "f32")
    idx.insert(key, prompt, pages)
    tier.note_published(sig, pages, None)
    freed = idx.evict(pool, N_LOG)               # demotes, stays in trie
    assert freed == N_LOG and pool.used == 0
    assert idx.held_pages == 0
    assert idx.host_held_pages == N_LOG == tier.host.used_pages
    assert idx.demoted_pages == N_LOG and idx.dropped_pages == 0

    m = idx.lookup(key, prompt)
    assert m is not None and m.full and m.needs_promotion and m.exact
    assert m.n_pages == N_LOG and len(m.host_refs) == N_LOG
    assert idx.sites_intact(m)
    # the engine handshake: promote the refs, install fresh device pages
    out_sig, _ = tier.promote(list(m.host_refs))
    assert out_sig == sig
    new = pool.alloc(len(m.host_refs))
    run = idx.install_promoted(m, new)
    assert run == list(new) and idx.promoted_pages == N_LOG
    assert idx.host_held_pages == 0 == tier.host.used_pages
    assert not idx.sites_intact(m)               # refs are gone now
    m2 = idx.lookup(key, prompt)                 # device-resident again
    assert m2.full and not m2.needs_promotion and list(m2.pages) == new
    idx.clear(pool)
    assert pool.used == 0 and tier.host.used_units == 0


def test_index_node_drop_prunes_host_subtree(tiny_cfg):
    """When the host tier refuses a NODE demotion the node drops and
    severs the lookup path — host refs stranded below it are freed and
    counted as drops, keeping host accounting leak-free."""
    pool, idx, tier, _ = _index_with_tier(tiny_cfg, 1)   # 1-page host tier
    key = (CANVAS, "spec")
    prompt = _toks(*range(10))                   # nodes n1,n2 + 2-page tail
    pages = pool.alloc(N_LOG)
    idx.insert(key, prompt, pages)
    tier.note_published((16, True, True, "f32"), pages, None)
    freed = idx.evict(pool, N_LOG)
    # tail (2 pages = 4 units) can't fit -> dropped; n2 demotes (fills
    # the tier); n1 demotion then fails -> n1 drops and prunes n2's ref
    assert freed == N_LOG and pool.used == 0
    assert idx.demoted_pages == 1
    assert idx.dropped_pages == 3 + 1            # tail(2) + n1 + pruned n2
    assert idx.host_held_pages == 0 == tier.host.used_pages
    assert idx.lookup(key, prompt) is None       # path is severed


def test_index_insert_supersedes_host_refs(tiny_cfg):
    """A fresh device publication of a host-resident entry frees the
    cold copy and resets the entry to the exact class."""
    pool, idx, tier, _ = _index_with_tier(tiny_cfg, 8, host_dtype="int8")
    key = (CANVAS, "spec")
    prompt = _toks(*range(10))
    pages = pool.alloc(N_LOG)
    idx.insert(key, prompt, pages)
    tier.note_published((16, True, True, "f32"), pages, None)
    idx.evict(pool, N_LOG)                       # all host-ward, int8
    m = idx.lookup(key, prompt)
    assert m.needs_promotion and not m.exact     # int8: partial-hit class
    # missing_slots treats host-resident depths as missing
    assert idx.missing_slots(key, prompt, N_LOG) == list(range(N_LOG))
    fresh = pool.alloc(N_LOG)
    assert idx.insert(key, prompt, fresh) == []
    assert idx.host_held_pages == 0 == tier.host.used_pages
    m2 = idx.lookup(key, prompt)
    assert m2.full and not m2.needs_promotion and m2.exact
    idx.clear(pool)
    assert pool.used == 0


# ---------------------------------------------------------------------------
# Session-level fidelity: demoted pages -> promoted pages -> decode
# ---------------------------------------------------------------------------

def _attach_cold(cfg, params, strat, pool, pages, tokens, active, arenas):
    pt = np.asarray([pool.page_table_row(pages, CANVAS)], np.int32)
    sess = DecodeSession(params, cfg, strategy=strat, backend="xla")
    sess.attach(tokens, active=jnp.asarray(active),
                kv_len=np.asarray([CANVAS], np.int32),
                arenas=arenas, page_table=pt)
    return sess


def _attach_hit(cfg, params, strat, pool, shared_pages, tokens, active,
                arenas):
    own = pool.alloc(N_LOG)
    pt = np.asarray([pool.page_table_row(list(shared_pages), CANVAS)],
                    np.int32)
    pool.retain(list(shared_pages))
    spec = SharedPrefix(row=0, pages=tuple(shared_pages),
                        reserve=tuple(own))
    sess = DecodeSession(params, cfg, strategy=strat, backend="xla")
    sess.attach(tokens, active=jnp.asarray(active),
                kv_len=np.asarray([CANVAS], np.int32),
                arenas=arenas, page_table=pt, shared=[spec])
    return sess


@pytest.mark.parametrize("ident", CACHED_IDENTS)
def test_demoted_promoted_hit_decode_fidelity(tiny_cfg, tiny_params,
                                              ident):
    """Acceptance (DESIGN.md §9): round-trip a cold prefill's pages
    through the host tier and decode a full hit off the promoted
    copies, in the host loop AND the compiled loop.  f32 demotion:
    byte-identical to the cold decode.  int8 demotion: promoted states
    within the quantization bound and the decode runs to completion
    (partial-hit class)."""
    cfg, params = tiny_cfg, tiny_params
    strat = _test_instance(ident)
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
    tokens = np.full((1, CANVAS), cfg.mask_id, np.int32)
    tokens[0, :8] = p
    active = np.zeros((1, CANVAS), bool)
    active[0, 8:16] = True
    pool = PagePool(cfg, n_pages=1 + 8 * N_LOG, page_size=PAGE,
                    strategy=strat)
    arenas = pool.arenas_for(strat)
    sig = cache_signature(cfg, strat)

    pub = pool.alloc(N_LOG)
    sa = _attach_cold(cfg, params, strat, pool, pub, tokens, active,
                      arenas)
    arenas_prefill = sa.state.cache.arenas       # immutable snapshot
    cold_run, _ = sa.run()

    def read(s, pages):
        return jax.tree.map(
            np.asarray, cache_lib.read_arena_pages(arenas_prefill, pages))

    orig = read(sig, pub)
    for host_dtype in ("f32", "int8"):
        tier = TierManager(HostPagePool(8), host_dtype=host_dtype,
                           read_pages=read)
        tier.note_published(sig, pub, None)
        refs = tier.demote(list(pub))
        assert refs is not None
        out_sig, blocks = tier.promote(refs)
        assert out_sig == sig and tier.host.used_units == 0
        if host_dtype == "f32":
            jax.tree.map(np.testing.assert_array_equal, orig, blocks)
        else:
            for kind, bufs in orig.items():
                for name, b in bufs.items():
                    if np.issubdtype(b.dtype, np.integer):
                        np.testing.assert_array_equal(
                            blocks[kind][name], b)
                        continue
                    bf = b.astype(np.float32)
                    err = np.abs(blocks[kind][name].astype(np.float32)
                                 - bf)
                    assert np.all(err <= _quant_bound(bf)), \
                        (ident, kind, name)
        promoted = pool.alloc(N_LOG)
        arenas2 = cache_lib.write_arena_pages(arenas_prefill, promoted,
                                              blocks)
        for mode in ("run", "run_compiled"):
            sb = _attach_hit(cfg, params, strat, pool, promoted, tokens,
                             active, arenas2)
            toks_b, _ = sb.run() if mode == "run" else sb.run_compiled()
            if host_dtype == "f32":              # exact class: bit-equal
                np.testing.assert_array_equal(
                    np.asarray(cold_run), np.asarray(toks_b),
                    err_msg=f"{ident}/{host_dtype}/{mode}")
            else:                                # allclose class
                assert int(np.max(np.asarray(sb.state.n_masked))) == 0, \
                    f"{ident}/{host_dtype}/{mode}"


# ---------------------------------------------------------------------------
# Engine-level: eviction pressure -> demote -> warm hit -> promote
# ---------------------------------------------------------------------------

def _hier_engine(cfg, params, host_pages, host_dtype="f32"):
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    return ServingEngine(cfg, params, max_batch=2, canvas_len=CANVAS,
                         pool_pages=9, page_size=PAGE, strategy=strat,
                         prefix_cache=True, host_pages=host_pages,
                         host_dtype=host_dtype)


def _pressure_cycle(eng, cfg):
    """cold(p0) -> two concurrent requests on a full pool (admission
    evicts p0's index entry) -> warm(p0).  Returns (cold, warm) outputs.
    """
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
               for _ in range(3)]
    u = eng.submit(prompts[0], gen_len=8)
    eng.run()
    cold = next(r for r in eng.done if r.uid == u).output
    for p in prompts[1:]:
        eng.submit(p, gen_len=8)
    eng.run()
    u = eng.submit(prompts[0], gen_len=8)
    eng.run()
    warm = next(r for r in eng.done if r.uid == u).output
    return cold, warm


def test_engine_hier_f32_promotion_byte_identical(tiny_cfg, tiny_params):
    """Headline: with the host tier on, the pressure-evicted prefix
    comes back as a FULL hit through promotion and its decode is
    byte-identical; with the tier off the same traffic is a re-prefill.
    Telemetry splits evictions into demoted + dropped exactly."""
    off = _hier_engine(tiny_cfg, tiny_params, host_pages=0)
    _pressure_cycle(off, tiny_cfg)
    assert off.prefix.evicted_pages == N_LOG
    assert off.prefix.demoted_pages == 0
    assert off.stats.prefix_dropped_pages == N_LOG
    off_full_hits = off.stats.prefix_full_hits

    eng = _hier_engine(tiny_cfg, tiny_params, host_pages=16)
    cold, warm = _pressure_cycle(eng, tiny_cfg)
    st = eng.stats
    assert st.prefix_demoted_pages == N_LOG
    assert st.prefix_dropped_pages == 0
    assert st.prefix_evicted_pages == (st.prefix_demoted_pages
                                       + st.prefix_dropped_pages)
    assert st.prefix_promoted_pages == N_LOG
    assert st.prefix_promotions == 1 and st.promotion_stalls == 0
    assert st.prefix_full_hits > off_full_hits   # host tier buys the hit
    assert st.peak_host_util > 0
    np.testing.assert_array_equal(cold, warm)    # exact class: bit-equal
    # both tiers account clean after the drain
    assert eng.pool.used == eng.prefix.held_pages
    assert eng.host_pool.used_pages == eng.prefix.host_held_pages
    dropped = eng.drop_prefix_cache()
    assert dropped > 0
    assert eng.pool.used == 0 and eng.host_pool.used_pages == 0


@pytest.mark.parametrize("host_dtype", ["int8", "auto"])
def test_engine_hier_quantized_promotion_completes(tiny_cfg, tiny_params,
                                                   host_dtype):
    """int8/auto cold tier: the promoted hit still lands (full hit,
    nonzero promotions), decodes to completion, and int8-touched
    entries are permanently marked inexact (partial-hit class)."""
    eng = _hier_engine(tiny_cfg, tiny_params, host_pages=16,
                       host_dtype=host_dtype)
    cold, warm = _pressure_cycle(eng, tiny_cfg)
    st = eng.stats
    assert st.prefix_demoted_pages == N_LOG
    assert st.prefix_promoted_pages == N_LOG and st.prefix_promotions == 1
    assert warm is not None and len(warm) == len(cold)
    if host_dtype == "int8":
        inexact = []

        def walk(node):
            if node.page is not None and not node.exact:
                inexact.append(node)
            for t in node.tails.values():
                if t.pages and not t.exact:
                    inexact.append(t)
            for c in node.children.values():
                walk(c)

        for root in eng.prefix.roots.values():
            walk(root)
        assert inexact                           # promoted != exact class
    eng.drop_prefix_cache()
    assert eng.pool.used == 0 and eng.host_pool.used_pages == 0

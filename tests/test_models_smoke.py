"""Per-arch reduced smoke tests (required deliverable f): every assigned
architecture instantiates a REDUCED same-family variant (2 layers,
d_model <= 512, <= 4 experts) and runs one forward + one train step on
CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch, reduced
from repro.models import transformer
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.trainer import train_step


def make_inputs(cfg, key, batch=2, n=32):
    if cfg.frontend == "audio":
        return {
            "frames": jax.random.normal(key, (batch, n, cfg.d_model),
                                        jnp.float32) * 0.02,
            "targets": jax.random.randint(key, (batch, n), 0,
                                          cfg.vocab_size),
        }
    if cfg.frontend == "vision":
        f = max(cfg.frontend_tokens, 4)
        return {
            "tokens": jax.random.randint(key, (batch, n - f), 0,
                                         cfg.vocab_size - 1),
            "patches": jax.random.normal(key, (batch, f, cfg.d_model),
                                         jnp.float32) * 0.02,
        }
    return {"tokens": jax.random.randint(key, (batch, n), 0,
                                         cfg.vocab_size - 1)}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward(arch):
    cfg = reduced(get_arch(arch))
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    inputs = make_inputs(cfg, key)
    logits, aux = transformer.forward_logits(params, cfg, inputs)
    n_expected = 32
    assert logits.shape[0] == 2
    assert logits.shape[1] == n_expected
    assert logits.shape[2] == cfg.vocab_size
    assert not bool(jnp.isnan(logits).any()), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(cfg, key)
    opt = init_opt_state(params)
    batch = make_inputs(cfg, key)
    if cfg.frontend is None:
        batch = {"tokens": batch["tokens"]}
    new_params, new_opt, metrics = train_step(
        params, opt, batch, key, cfg=cfg, opt_cfg=AdamWConfig(lr=1e-3))
    assert np.isfinite(float(metrics["loss"])), arch
    # grad_norm is finite and positive, OR the nonfinite-skip guard fired
    gn = float(metrics["grad_norm"])
    assert (np.isfinite(gn) and gn > 0) or \
        float(metrics["nonfinite_grads"]) == 1.0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda p, q: float(jnp.abs(
            p.astype(jnp.float32) - q.astype(jnp.float32)).sum()),
            params, new_params))
    assert delta > 0


@pytest.mark.parametrize("arch", ["gemma2-2b", "recurrentgemma-9b",
                                  "mamba2-370m", "mixtral-8x22b"])
def test_reduced_decode_step(arch):
    """Non-dense families also serve: one SPA/dense refinement step."""
    from repro.dlm import decoding
    cfg = reduced(get_arch(arch))
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(cfg, key)
    prompt = jax.random.randint(key, (2, 8), 0, cfg.vocab_size - 1)
    toks, info = decoding.decode(params, cfg, prompt, gen_len=4,
                                 max_steps=6)
    assert toks.shape == (2, 12)
    assert int((toks == cfg.mask_id).sum()) == 0 or info["steps"] == 6

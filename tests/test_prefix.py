"""Shared-prefix radix cache (DESIGN.md §6): index mechanics, partial
prefill bit-exactness, copy-on-write isolation, and the headline
guarantee — a prefix-hit decode is byte-identical to a cold decode for
every registered strategy on both kernel backends in both run modes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import strategy as strategy_lib
from repro.core.strategy import (AttnOutCache, SPACache, ValueProxyCache,
                                 WindowCache)
from repro.dlm import decoding
from repro.dlm.session import DecodeSession, SharedPrefix
from repro.serving.engine import ServingEngine
from repro.serving.pool import PagePool
from repro.serving.prefix import PrefixIndex

PAGE = 4
CANVAS = 16
N_LOG = CANVAS // PAGE


def _test_instance(ident: str):
    inc = ident.endswith("+inc")
    base = ident.split("+")[0]
    cls = strategy_lib.REGISTRY[base]
    if cls is SPACache:
        return SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                        incremental_ident=inc)
    if cls is ValueProxyCache:
        return ValueProxyCache(projection=base, rho=0.3)
    if cls is WindowCache:
        return WindowCache(locality_window=8, rho=0.3)
    if cls is AttnOutCache:
        return AttnOutCache(rho=0.5)
    return cls()


CACHED_IDENTS = sorted(i for i in strategy_lib.REGISTRY
                       if strategy_lib.REGISTRY[i].uses_cache) \
    + ["singular+inc"]


# ---------------------------------------------------------------------------
# Radix index mechanics (no model involved)
# ---------------------------------------------------------------------------

def _toks(*vals):
    return np.asarray(vals, np.int32)


def test_index_insert_lookup_full_and_partial(tiny_cfg):
    pool = PagePool(tiny_cfg, n_pages=32, page_size=PAGE)
    idx = PrefixIndex(PAGE)
    key = (CANVAS, "spec")
    prompt = _toks(*range(10))            # 2 full pages + 2 loose tokens
    pages = pool.alloc(N_LOG)             # path(2) + tail(2) for row=16
    assert idx.insert(key, prompt, pages) == []
    # exact re-lookup: full hit, all 4 pages in order
    m = idx.lookup(key, prompt)
    assert m is not None and m.full and list(m.pages) == pages
    # same pages, different tail tokens: partial hit on the 2 full pages
    m2 = idx.lookup(key, _toks(*range(8), 99, 98))
    assert m2 is not None and not m2.full and list(m2.pages) == pages[:2]
    # extension: longer prompt matches the page-aligned prefix
    m3 = idx.lookup(key, _toks(*range(13)))
    assert m3 is not None and not m3.full and list(m3.pages) == pages[:2]
    # partial_ok=False keeps only full hits
    assert idx.lookup(key, _toks(*range(13)), partial_ok=False) is None
    # a different layout root never matches
    assert idx.lookup((CANVAS * 2, "spec"), prompt) is None
    # first publisher wins: re-inserting the same path rejects the dupes
    dup = pool.alloc(N_LOG)
    assert sorted(idx.insert(key, prompt, dup)) == sorted(dup)


def test_index_eviction_lru_and_refcount_gating(tiny_cfg):
    pool = PagePool(tiny_cfg, n_pages=32, page_size=PAGE)
    idx = PrefixIndex(PAGE)
    key = (CANVAS, "spec")
    pa = pool.alloc(N_LOG)
    pb = pool.alloc(N_LOG)
    idx.insert(key, _toks(*range(10)), pa)
    idx.insert(key, _toks(*range(100, 110)), pb)
    idx.lookup(key, _toks(*range(10)))    # touch A: B becomes LRU
    before = pool.available
    freed = idx.evict(pool, 1)            # evicts B's tail first (LRU)
    assert freed >= 1 and pool.available == before + freed
    assert idx.lookup(key, _toks(*range(10))).full   # A survives
    # reader holds block eviction entirely
    m = idx.lookup(key, _toks(*range(10)))
    pool.retain(list(m.pages))
    assert idx.evict(pool, 64) < idx.held_pages + 64  # can't free A
    assert idx.lookup(key, _toks(*range(10))).full
    pool.release(list(m.pages))
    idx.evict(pool, 64)
    assert idx.held_pages == 0
    idx.clear(pool)
    assert pool.used == 0 and not pool.refcounts


def test_index_deep_eviction_is_leaf_first(tiny_cfg):
    """Evicting a mid-path node before its descendants would leave
    unreachable pages; eviction must free deepest entries first."""
    pool = PagePool(tiny_cfg, n_pages=64, page_size=PAGE)
    idx = PrefixIndex(PAGE)
    key = (CANVAS, "spec")
    idx.insert(key, _toks(*range(8)), pool.alloc(N_LOG))       # 2+2
    idx.insert(key, _toks(*range(12)), [None, None]
               + pool.alloc(2))                                # deepen
    # evict everything one page at a time; at every point a lookup walk
    # never crosses a page-less node into a page-bearing one
    while idx.held_pages:
        idx.evict(pool, 1)

        def check(node, parent_has):
            ok = True
            for child in node.children.values():
                if child.page is not None and not parent_has:
                    return False
                ok = ok and check(child, child.page is not None)
            return ok

        for root in idx.roots.values():
            assert check(root, True)
    assert pool.used == 0


# ---------------------------------------------------------------------------
# Partial prefill bit-exactness (the suffix-only forward)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ident", CACHED_IDENTS)
def test_prefill_partial_matches_cold(tiny_cfg, tiny_params, ident):
    """Given exact prefix K/V, ``prefill_partial`` reproduces the cold
    prefill's suffix rows up to XLA op-scheduling error (the cold path
    compiles a layer scan, the partial path an unrolled loop — fusion
    grouping, not math, differs), and writes exact zeros at prefix
    rows so the zero-page write table drops them."""
    cfg, params = tiny_cfg, tiny_params
    strat = _test_instance(ident)
    proxies = strat.build_proxies(params, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, CANVAS), 0,
                              cfg.vocab_size - 1)
    kv = jnp.asarray([CANVAS, CANVAS], jnp.int32)
    _, cold = decoding.prefill(params, cfg, {"tokens": toks}, proxies,
                               strat, kv_len=kv)
    view = {kind: {nm: bufs[nm] for nm in ("k", "v")}
            for kind, bufs in cold.items()}
    s0 = 8
    part = decoding.prefill_partial(params, cfg, {"tokens": toks}, view,
                                    s0, kv_len=kv, spa_proxies=proxies,
                                    strategy=strat)
    for kind, bufs in part.items():
        for name, val in bufs.items():
            np.testing.assert_allclose(
                np.asarray(val)[:, :, s0:].astype(np.float32),
                np.asarray(cold[kind][name])[:, :, s0:]
                .astype(np.float32),
                rtol=2e-3, atol=1e-5, err_msg=f"{ident}:{kind}:{name}")
            assert np.abs(np.asarray(val)[:, :, :s0]).max() == 0.0


# ---------------------------------------------------------------------------
# Hit-decode == cold-decode (headline guarantee)
# ---------------------------------------------------------------------------

def _cold_attach(cfg, params, strat, backend, pool, pages, tokens,
                 active, arenas):
    pt = np.asarray([pool.page_table_row(pages, CANVAS)], np.int32)
    sess = DecodeSession(params, cfg, strategy=strat, backend=backend)
    sess.attach(tokens, active=jnp.asarray(active),
                kv_len=np.asarray([CANVAS], np.int32),
                arenas=arenas, page_table=pt)
    return sess


def _hit_attach(cfg, params, strat, backend, pool, shared_pages, m,
                tokens, active, arenas_prefill):
    """Attach with the first ``m`` logical pages shared (read-only) and
    the rest private; m == N_LOG is a full hit (no prefill forward)."""
    own = pool.alloc(N_LOG)
    pt_pages = list(shared_pages[:m]) + own[m:]
    pt = np.asarray([pool.page_table_row(pt_pages, CANVAS)], np.int32)
    pool.retain(list(shared_pages[:m]))
    spec = SharedPrefix(row=0, pages=tuple(shared_pages[:m]),
                        reserve=tuple(own[:m]))
    sess = DecodeSession(params, cfg, strategy=strat, backend=backend)
    sess.attach(tokens, active=jnp.asarray(active),
                kv_len=np.asarray([CANVAS], np.int32),
                arenas=arenas_prefill, page_table=pt, shared=[spec])
    return sess


def _gather_pages(arenas, pages):
    from repro.kernels.backend import XLA_BACKEND
    pt = jnp.asarray([pages], jnp.int32)
    return jax.tree.map(
        lambda a: np.asarray(XLA_BACKEND.gather_pages(a, pt)), arenas)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("ident", CACHED_IDENTS)
def test_prefix_hit_decode_byte_identical(tiny_cfg, tiny_params, ident,
                                          backend):
    """Acceptance: a FULL prefix hit (the only hit kind an exact prompt
    rematch can produce — full runs are always published) decodes
    byte-identically to the cold decode, in both the host loop and the
    compiled loop; a PARTIAL hit attaches, partial-prefills, decodes to
    completion; and in every case the shared (index) pages survive the
    hit decode byte-unchanged (copy-on-write)."""
    cfg, params = tiny_cfg, tiny_params
    strat = _test_instance(ident)
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
    tokens = np.full((1, CANVAS), cfg.mask_id, np.int32)
    tokens[0, :8] = p
    active = np.zeros((1, CANVAS), bool)
    active[0, 8:16] = True
    pool = PagePool(cfg, n_pages=1 + 8 * N_LOG, page_size=PAGE,
                    strategy=strat)
    arenas = pool.arenas_for(strat)

    pub = pool.alloc(N_LOG)       # "published" pages: prefill-time states
    sa = _cold_attach(cfg, params, strat, backend, pool, pub, tokens,
                      active, arenas)
    arenas_prefill = sa.state.cache.arenas
    shared_before = _gather_pages(arenas_prefill, pub)
    cold_run, _ = sa.run()

    sc = _cold_attach(cfg, params, strat, backend, pool, pool.alloc(N_LOG),
                      tokens, active, arenas_prefill)
    cold_compiled, _ = sc.run_compiled()
    np.testing.assert_array_equal(np.asarray(cold_run),
                                  np.asarray(cold_compiled))

    for m, mode in ((N_LOG, "run"), (N_LOG, "run_compiled"),
                    (2, "run"), (2, "run_compiled")):
        sb = _hit_attach(cfg, params, strat, backend, pool, pub, m,
                         tokens, active, arenas_prefill)
        toks_b, _ = sb.run() if mode == "run" else sb.run_compiled()
        if m == N_LOG:   # full hit: bit-exact end to end
            np.testing.assert_array_equal(
                np.asarray(cold_run), np.asarray(toks_b),
                err_msg=f"{ident}/{backend}/{mode}/m={m}")
        else:            # partial hit: drift-managed, must complete
            assert int(np.max(np.asarray(sb.state.n_masked))) == 0
        # COW: the hit decode never mutated the shared pages
        shared_after = _gather_pages(sb.state.cache.arenas, pub)
        jax.tree.map(np.testing.assert_array_equal, shared_before,
                     shared_after)


def test_cow_commit_never_mutates_sibling_view(tiny_cfg, tiny_params):
    """Two concurrent readers of the same shared pages: one decodes
    (commits -> COW), the sibling's gathered view of its prefix is
    byte-unchanged, and both decodes produce identical tokens."""
    cfg, params = tiny_cfg, tiny_params
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
    tokens = np.full((1, CANVAS), cfg.mask_id, np.int32)
    tokens[0, :8] = p
    active = np.zeros((1, CANVAS), bool)
    active[0, 8:16] = True
    pool = PagePool(cfg, n_pages=1 + 4 * N_LOG, page_size=PAGE,
                    strategy=strat)
    arenas = pool.arenas_for(strat)
    pub = pool.alloc(N_LOG)
    sa = _cold_attach(cfg, params, strat, "xla", pool, pub, tokens,
                      active, arenas)
    arenas_prefill = sa.state.cache.arenas

    sb = _hit_attach(cfg, params, strat, "xla", pool, pub, 2, tokens,
                     active, arenas_prefill)
    sc = _hit_attach(cfg, params, strat, "xla", pool, pub, 2, tokens,
                     active, arenas_prefill)
    view_c0 = _gather_pages(sc.state.cache.arenas, pub[:2])
    for _ in range(3):
        sb.step()                 # commits into (COW copies of) pages
    # sibling C still reads the pristine prefill states
    view_c1 = _gather_pages(sc.state.cache.arenas, pub[:2])
    jax.tree.map(np.testing.assert_array_equal, view_c0, view_c1)
    toks_b, _ = sb.run()
    toks_c, _ = sc.run()
    np.testing.assert_array_equal(np.asarray(toks_b), np.asarray(toks_c))


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

def _engine(cfg, params, strategy=None, pool_pages=40, **kw):
    return ServingEngine(cfg, params, max_batch=2, canvas_len=CANVAS,
                         pool_pages=pool_pages, page_size=PAGE,
                         strategy=strategy, prefix_cache=True, **kw)


def test_engine_resubmit_is_full_hit_and_byte_identical(tiny_cfg,
                                                        tiny_params):
    """The engine-level headline check: a resubmitted prompt full-hits
    the index, skips its prefill forward, and decodes byte-identically
    to its own cold first run."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    eng = _engine(tiny_cfg, tiny_params, strat)
    rng = np.random.default_rng(0)
    p = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    u0 = eng.submit(p, gen_len=8)
    eng.run()
    assert eng.stats.prefix_hits == 0
    u1 = eng.submit(p, gen_len=8)
    eng.run()
    assert eng.stats.prefix_full_hits == 1
    assert eng.stats.prefix_tokens_saved == CANVAS
    out = {r.uid: r.output for r in eng.done}
    np.testing.assert_array_equal(out[u0], out[u1])


def test_engine_multiturn_extension_deepens_the_trie(tiny_cfg,
                                                     tiny_params):
    """A growing transcript partial-hits the previous turn's pages; the
    unmatched extension is published, so resubmitting the longer prompt
    full-hits.  ``row_len`` reservation keeps the layout key fixed."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    eng = _engine(tiny_cfg, tiny_params, strat, pool_pages=64)
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    eng.submit(p1, gen_len=4, row_len=CANVAS)
    eng.run()
    p2 = np.concatenate([p1, rng.integers(
        0, tiny_cfg.vocab_size - 1, 4).astype(np.int32)])
    eng.submit(p2, gen_len=4, row_len=CANVAS)      # partial hit (2 pages)
    eng.run()
    assert eng.stats.prefix_hits == 1
    assert eng.stats.prefix_full_hits == 0
    assert eng.stats.prefix_tokens_saved == 8
    u2 = eng.submit(p2, gen_len=4, row_len=CANVAS)  # full hit now
    eng.run()
    assert eng.stats.prefix_full_hits == 1
    assert [r for r in eng.done if r.uid == u2][0].output is not None


def test_engine_prefix_off_matches_on_for_cold_traffic(tiny_cfg,
                                                       tiny_params):
    """With only distinct prompts (all misses), the prefix engine serves
    byte-identically to a prefix-off engine — publication copies never
    leak into decode state."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, tiny_cfg.vocab_size - 1, 6 + i)
               .astype(np.int32) for i in range(4)]

    def serve(prefix_cache):
        eng = ServingEngine(tiny_cfg, tiny_params, max_batch=2,
                            canvas_len=CANVAS, pool_pages=40,
                            page_size=PAGE, strategy=strat,
                            prefix_cache=prefix_cache)
        uids = [eng.submit(p, gen_len=6) for p in prompts]
        eng.run()
        out = {r.uid: r.output for r in eng.done}
        return [out[u] for u in uids]

    for a, b in zip(serve(True), serve(False)):
        np.testing.assert_array_equal(a, b)


def test_engine_preemption_with_prefix_cache_matches_off(tiny_cfg,
                                                         tiny_params):
    """Preempt/resume under a tight pool with the index competing for
    pages: same outputs as a prefix-off engine (resumed requests never
    consult the index), and the index evicts instead of starving."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    rng = np.random.default_rng(7)
    smalls = [rng.integers(0, tiny_cfg.vocab_size - 1, 4)
              .astype(np.int32) for _ in range(2)]
    big = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)

    def serve(prefix_cache):
        eng = ServingEngine(tiny_cfg, tiny_params, max_batch=2,
                            canvas_len=CANVAS, pool_pages=5,
                            page_size=PAGE, strategy=strat,
                            prefix_cache=prefix_cache)
        uids = [eng.submit(p, gen_len=4) for p in smalls]

        def on_step(e):
            if e.stats.steps == 2:
                uids.append(e.submit(big, gen_len=8, priority=5))

        eng.run(on_step=on_step)
        out = {r.uid: r.output for r in eng.done}
        return [out[u] for u in uids], eng

    out_on, eng_on = serve(True)
    out_off, _ = serve(False)
    assert eng_on.stats.preemptions > 0
    for a, b in zip(out_on, out_off):
        np.testing.assert_array_equal(a, b)


def test_engine_admission_evicts_index_before_preempting(tiny_cfg,
                                                         tiny_params):
    """A queued request short on pages reclaims reader-less index pages
    (LRU) before any running request is preempted."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    eng = ServingEngine(tiny_cfg, tiny_params, max_batch=2,
                        canvas_len=CANVAS, pool_pages=9, page_size=PAGE,
                        strategy=strat, prefix_cache=True)
    rng = np.random.default_rng(9)
    eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
               .astype(np.int32), gen_len=8)
    eng.run()
    assert eng.stats.prefix_published == N_LOG    # index holds 4 of 8
    for _ in range(2):                            # 8 pages, only 4 free
        eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
                   .astype(np.int32), gen_len=8)
    eng.run()
    assert eng.stats.prefix_evicted_pages > 0
    assert eng.stats.preemptions == 0
    assert eng.stats.requests_done == 3


def test_engine_no_eviction_for_unadmittable_candidate(tiny_cfg,
                                                       tiny_params):
    """A candidate that cannot be admitted even after eviction (no free
    slot, no preemptible victims) must NOT destroy LRU index entries —
    eviction only runs when it can actually complete an admission."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    eng = ServingEngine(tiny_cfg, tiny_params, max_batch=1,
                        canvas_len=CANVAS, pool_pages=10, page_size=PAGE,
                        strategy=strat, prefix_cache=True)
    rng = np.random.default_rng(13)
    eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
               .astype(np.int32), gen_len=8)
    eng.run()                              # publishes 4 index pages
    assert eng.prefix.held_pages == N_LOG
    eng.submit(rng.integers(0, tiny_cfg.vocab_size - 1, 8)
               .astype(np.int32), gen_len=8, priority=5)
    low = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    s0 = eng.stats.steps

    def on_step(e):
        if e.stats.steps == s0 + 1:        # slot held by priority 5:
            e.submit(low, gen_len=8)       # low-pri candidate stalls

    eng.run(on_step=on_step)
    assert eng.stats.requests_done == 3
    assert eng.stats.prefix_evicted_pages == 0
    assert eng.prefix.held_pages == N_LOG  # entry survived the stall


def test_engine_duplicate_prompts_publish_once(tiny_cfg, tiny_params):
    """Identical prompts admitted in ONE batch (retries / n>1 samples)
    all plan before the first publishes; the read-only probe must stop
    the later ones from alloc+copying a full run that insert would
    reject wholesale."""
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3)
    eng = ServingEngine(tiny_cfg, tiny_params, max_batch=4,
                        canvas_len=CANVAS, pool_pages=40, page_size=PAGE,
                        strategy=strat, prefix_cache=True)
    rng = np.random.default_rng(17)
    p = rng.integers(0, tiny_cfg.vocab_size - 1, 8).astype(np.int32)
    uids = [eng.submit(p, gen_len=8) for _ in range(4)]
    eng.run()
    assert eng.stats.requests_done == 4
    assert eng.stats.prefix_published == N_LOG      # one run, not four
    assert eng.stats.prefix_publish_skipped == 0
    assert eng.prefix.held_pages == N_LOG
    out = {r.uid: r.output for r in eng.done}
    for u in uids[1:]:                              # rows are identical
        np.testing.assert_array_equal(out[uids[0]], out[u])


def test_engine_submit_rejects_unschedulable_gen_len(tiny_cfg,
                                                     tiny_params):
    eng = _engine(tiny_cfg, tiny_params, SPACache(rank=16))
    with pytest.raises(ValueError):
        eng.submit(np.arange(4, dtype=np.int32), gen_len=0)
    with pytest.raises(ValueError):
        eng.submit(np.arange(4, dtype=np.int32), gen_len=CANVAS + 1)

"""Flash (chunked) attention vs dense oracle: shape / feature sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention, reference_attention


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("sq,skv,h,kvh,hd", [
    (16, 16, 4, 4, 8),       # MHA
    (32, 64, 4, 2, 16),      # GQA
    (7, 33, 8, 1, 16),       # MQA, ragged sizes
    (64, 128, 6, 3, 20),     # non-pow2 head dim
])
def test_dense_matches_reference(sq, skv, h, kvh, hd):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (2, sq, h, hd))
    k = rand(ks[1], (2, skv, kvh, hd))
    v = rand(ks[2], (2, skv, kvh, hd))
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = reference_attention(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [4, 16, 64])
def test_windowed_banded(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 128, 2, 8))
    k = rand(ks[1], (1, 128, 2, 8))
    v = rand(ks[2], (1, 128, 2, 8))
    out = flash_attention(q, k, v, window=window, banded=True,
                          block_q=16, block_k=16)
    ref = reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_gathered_queries():
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    kq = 24
    q = rand(ks[0], (2, kq, 4, 8))
    k = rand(ks[1], (2, 96, 2, 8))
    v = rand(ks[2], (2, 96, 2, 8))
    qpos = jnp.sort(jax.random.randint(ks[3], (2, kq), 0, 96), axis=-1)
    out = flash_attention(q, k, v, q_positions=qpos, block_q=8,
                          block_k=32)
    ref = reference_attention(q, k, v, q_positions=qpos)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    # windowed gathered
    out_w = flash_attention(q, k, v, q_positions=qpos, window=16,
                            block_q=8, block_k=32)
    ref_w = reference_attention(q, k, v, q_positions=qpos, window=16)
    np.testing.assert_allclose(out_w, ref_w, rtol=2e-4, atol=2e-4)


def test_gathered_banded_dynamic_start():
    """Stratified-style gathered queries with q_span bound + block skip."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    n = 512
    q = rand(ks[0], (1, 32, 2, 8))
    k = rand(ks[1], (1, n, 1, 8))
    v = rand(ks[2], (1, n, 1, 8))
    # stratified: one query per 16-position stratum
    qpos = (jnp.arange(32) * 16 + 3)[None, :]
    out = flash_attention(q, k, v, q_positions=qpos, window=32,
                          banded=True, q_span=16 * 8 + 64, block_q=8,
                          block_k=32)
    ref = reference_attention(q, k, v, q_positions=qpos, window=32)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_softcap():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (1, 32, 2, 8)) * 4
    k = rand(ks[1], (1, 32, 2, 8)) * 4
    v = rand(ks[2], (1, 32, 2, 8))
    out = flash_attention(q, k, v, soft_cap=20.0, block_q=8, block_k=8)
    ref = reference_attention(q, k, v, soft_cap=20.0)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_int8_kv_scales():
    from repro.core.cache import dequantize_rows, quantize_rows
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = rand(ks[0], (1, 16, 2, 8))
    k = rand(ks[1], (1, 48, 2, 8))
    v = rand(ks[2], (1, 48, 2, 8))
    kq, kscale = quantize_rows(k)
    vq, vscale = quantize_rows(v)
    out = flash_attention(kq * 0 + q if False else q, kq, vq,
                          k_scale=kscale, v_scale=vscale,
                          block_q=8, block_k=16)
    ref = reference_attention(q, dequantize_rows(kq, kscale),
                              dequantize_rows(vq, vscale))
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_bf16_inputs():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = rand(ks[0], (1, 32, 2, 8)).astype(jnp.bfloat16)
    k = rand(ks[1], (1, 32, 2, 8)).astype(jnp.bfloat16)
    v = rand(ks[2], (1, 32, 2, 8)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=8, block_k=8)
    ref = reference_attention(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=3e-2,
                               atol=3e-2)

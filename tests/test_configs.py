"""Config registry invariants for every assigned architecture."""
import numpy as np
import pytest

from repro.configs import (ARCHS, ASSIGNED, SHAPES, SUBQUADRATIC,
                           get_arch, reduced, supports_shape)
from repro.configs.base import ATTENTION_KINDS

# Published parameter counts (approximate, ±25% tolerance for tokenizer /
# head-dim conventions).
EXPECTED_PARAMS = {
    "gemma2-2b": 2.6e9,
    "deepseek-67b": 67e9,
    "recurrentgemma-9b": 9e9,
    "hubert-xlarge": 1.0e9,
    "internlm2-1.8b": 1.9e9,
    "internvl2-76b": 70e9,          # language backbone only
    "qwen3-moe-235b-a22b": 235e9,
    "mamba2-370m": 0.37e9,
    "mixtral-8x22b": 141e9,
    "h2o-danube-3-4b": 4e9,
}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_exact_dims_match_assignment(arch):
    cfg = get_arch(arch)
    table = {
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mamba2-370m": (48, 1024, None, None, 0, 50280),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    }[arch]
    L, d, h, kv, dff, vocab = table
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.vocab_size == vocab
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == dff or (cfg.moe and cfg.moe.d_ff_expert == dff)
    assert cfg.source  # citation present


@pytest.mark.parametrize("arch", list(EXPECTED_PARAMS))
def test_param_count_plausible(arch):
    cfg = get_arch(arch)
    got = cfg.param_count()
    want = EXPECTED_PARAMS[arch]
    assert 0.6 * want < got < 1.5 * want, (arch, got, want)


def test_moe_active_params():
    cfg = get_arch("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert 12e9 < active < 35e9          # "a22b"
    assert active < cfg.param_count() / 4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_layer_kind_indexing(arch):
    cfg = get_arch(arch)
    counts = {}
    for l in range(cfg.n_layers):
        kind = cfg.kind_of_layer(l)
        assert cfg.kind_index(l) == counts.get(kind, 0)
        counts[kind] = counts.get(kind, 0) + 1
    for kind, c in counts.items():
        assert cfg.n_layers_of_kind(kind) == c


def test_shape_support_matrix():
    total_live = 0
    for arch in ASSIGNED:
        cfg = get_arch(arch)
        for s in SHAPES.values():
            if supports_shape(cfg, s):
                total_live += 1
    assert total_live == 33              # 40 pairs - 7 documented skips
    assert not supports_shape(get_arch("hubert-xlarge"),
                              SHAPES["decode_32k"])
    assert not supports_shape(get_arch("deepseek-67b"),
                              SHAPES["long_500k"])
    assert supports_shape(get_arch("mamba2-370m"), SHAPES["long_500k"])


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_constraints(arch):
    cfg = reduced(get_arch(arch))
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    assert cfg.param_dtype == "float32"


def test_mamba2_spa_inapplicable():
    assert get_arch("mamba2-370m").spa.identifier == "none"


def test_paper_models_present():
    assert "llada-8b" in ARCHS and "dream-7b" in ARCHS
    llada = ARCHS["llada-8b"]
    assert llada.spa.layer_peak == 24 and llada.spa.rho_peak == 0.25

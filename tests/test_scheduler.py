"""UnmaskScheduler protocol + device-resident decode loop.

(a) registry covers every commit policy and the legacy DecodeSettings
    knobs resolve to byte-identical schedulers,
(b) for EVERY registered scheduler, ``run_compiled()`` (one
    ``lax.while_loop``, refresh via ``lax.cond``) produces byte-identical
    tokens to the host ``run()`` loop under the same rng/settings,
(c) BlockScheduler realizes the semi-AR §2.2 schedule as data (strict
    left-to-right block order, no host loop),
(d) stochastic schedulers replay exactly from the rng chain threaded
    through ``DecodeState``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.strategy import NoCache, SPACache
from repro.dlm import decoding, scheduler as sched_lib
from repro.dlm.decoding import DecodeSettings
from repro.dlm.scheduler import (BlockScheduler, ConfidenceScheduler,
                                 EntropyScheduler,
                                 ParallelThresholdScheduler,
                                 RandomOrderScheduler, TemperatureSampler,
                                 resolve_scheduler)
from repro.dlm.session import DecodeSession
from repro.models import transformer


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_arch("internlm2-1.8b"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0,
                                cfg.vocab_size - 1)
    return cfg, params, prompt


def _test_instance(name: str) -> sched_lib.UnmaskScheduler:
    """A small test-sized instance of each registered scheduler."""
    return {
        "confidence": ConfidenceScheduler(),
        "parallel": ParallelThresholdScheduler(threshold=0.05,
                                               max_parallel=4),
        "entropy": EntropyScheduler(threshold=3.0, max_parallel=4),
        "temperature": TemperatureSampler(temperature=0.8),
        "random_order": RandomOrderScheduler(),
        "block": BlockScheduler(block_len=4),
    }[name]


def test_registry_covers_all_schedulers():
    assert set(sched_lib.SCHEDULERS) == {
        "confidence", "parallel", "entropy", "temperature",
        "random_order", "block"}
    for name, cls in sched_lib.SCHEDULERS.items():
        inst = _test_instance(name)
        assert isinstance(inst, cls) and cls.name == name
        hash(inst)                      # lane keys require hashability
        assert sched_lib.scheduler_from_name(name) == cls()


def test_settings_knobs_resolve_to_schedulers():
    """The legacy DecodeSettings parallel knobs are a spec bridge."""
    assert resolve_scheduler(DecodeSettings()) == ConfidenceScheduler()
    assert resolve_scheduler(
        DecodeSettings(parallel_threshold=0.1, max_parallel=2)
    ) == ParallelThresholdScheduler(threshold=0.1, max_parallel=2)
    # call-time scheduler wins over the settings knobs
    assert resolve_scheduler(
        DecodeSettings(parallel_threshold=0.1),
        RandomOrderScheduler()) == RandomOrderScheduler()


@pytest.mark.parametrize("name", sorted(sched_lib.SCHEDULERS))
def test_run_compiled_matches_host_loop(small, name):
    """(b) byte-identical host/device decode per scheduler, with
    periodic refresh exercised inside the while_loop."""
    cfg, params, prompt = small
    strat = SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                     refresh_interval=3)

    def fresh():
        sess = DecodeSession(params, cfg, strategy=strat,
                             scheduler=_test_instance(name))
        sess.prefill(prompt, gen_len=6, rng=7)
        return sess

    host = fresh()
    toks_h, info_h = host.run()
    comp = fresh()
    toks_c, info_c = comp.run_compiled()
    np.testing.assert_array_equal(np.asarray(toks_h), np.asarray(toks_c))
    assert int((np.asarray(toks_c) == cfg.mask_id).sum()) == 0
    assert info_h["steps"] == info_c["steps"]
    assert host.refresh_count == comp.refresh_count >= 1


def test_run_compiled_matches_host_no_cache(small):
    """The compiled loop also covers cache-less (NoCache) sessions,
    where the refresh cond is statically elided."""
    cfg, params, prompt = small
    outs = []
    for runner in ("run", "run_compiled"):
        sess = DecodeSession(params, cfg, strategy=NoCache())
        sess.prefill(prompt, gen_len=6)
        toks, _ = getattr(sess, runner)()
        outs.append(np.asarray(toks))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_scheduler_path_reproduces_settings_path(small):
    """ConfidenceScheduler / ParallelThresholdScheduler reproduce the
    pre-refactor settings-flag decode outputs exactly."""
    cfg, params, prompt = small
    # sequential: default settings == explicit ConfidenceScheduler
    t_set, _ = decoding.decode(params, cfg, prompt, gen_len=8)
    t_sch, _ = decoding.decode(params, cfg, prompt, gen_len=8,
                               scheduler=ConfidenceScheduler())
    np.testing.assert_array_equal(np.asarray(t_set), np.asarray(t_sch))
    # parallel: threshold knobs == explicit ParallelThresholdScheduler
    t_set, _ = decoding.decode(
        params, cfg, prompt, gen_len=8,
        settings=DecodeSettings(parallel_threshold=0.05, max_parallel=4))
    t_sch, _ = decoding.decode(
        params, cfg, prompt, gen_len=8,
        scheduler=ParallelThresholdScheduler(threshold=0.05,
                                             max_parallel=4))
    np.testing.assert_array_equal(np.asarray(t_set), np.asarray(t_sch))


def test_block_scheduler_commits_blocks_in_order(small):
    """(c) semi-AR as data: with BlockScheduler, no position in block
    i+1 commits while block i still has open slots."""
    cfg, params, prompt = small
    block_len, gen_len = 4, 8
    sess = DecodeSession(params, cfg,
                         scheduler=BlockScheduler(block_len=block_len))
    sess.prefill(prompt, gen_len=gen_len)
    p_len = prompt.shape[1]
    commit_step = np.full((2, gen_len), -1)
    for step in range(1, 2 * gen_len + 1):
        sess.step()
        gen = np.asarray(sess.tokens)[:, p_len:]
        newly = np.logical_and(gen != cfg.mask_id, commit_step < 0)
        commit_step[newly] = step
        if sess.done:
            break
    assert (commit_step >= 0).all()
    for row in commit_step:
        assert row[:block_len].max() < row[block_len:].min()


def test_block_scheduler_respects_active_mask(small):
    """Window derivation starts at the first ACTIVE position, so block
    windows stay inside the generation span."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg,
                         scheduler=BlockScheduler(block_len=4))
    sess.prefill(prompt, gen_len=8)
    toks, _ = sess.run_compiled()
    toks = np.asarray(toks)
    np.testing.assert_array_equal(toks[:, :prompt.shape[1]],
                                  np.asarray(prompt))
    assert int((toks == cfg.mask_id).sum()) == 0


def test_stochastic_schedulers_replay_from_seed(small):
    """(d) same rng seed -> identical decode; the key chain lives in
    DecodeState, so host and compiled loops consume it identically."""
    cfg, params, prompt = small
    for scheduler in (TemperatureSampler(temperature=0.8),
                      RandomOrderScheduler()):
        outs = []
        for _ in range(2):
            sess = DecodeSession(params, cfg, scheduler=scheduler)
            sess.prefill(prompt, gen_len=6, rng=123)
            toks, _ = sess.run()
            outs.append(np.asarray(toks))
        np.testing.assert_array_equal(outs[0], outs[1])
        # the chain advanced (rng was actually consumed)
        assert not np.array_equal(
            np.asarray(sess.state.rng),
            np.asarray(jax.random.PRNGKey(123)))


def test_rng_required_for_stochastic_is_defaulted(small):
    """Omitting rng= with a stochastic scheduler falls back to a seeded
    default key rather than crashing (documented in _as_rng)."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg, scheduler=RandomOrderScheduler())
    sess.prefill(prompt, gen_len=4)
    assert sess.state.rng is not None
    toks, _ = sess.run()
    assert int((np.asarray(toks) == cfg.mask_id).sum()) == 0


def test_parallel_scheduler_commits_more_per_step(small):
    cfg, params, prompt = small
    steps = {}
    for name, scheduler in (
            ("seq", ConfidenceScheduler()),
            ("par", ParallelThresholdScheduler(threshold=0.05,
                                               max_parallel=4))):
        sess = DecodeSession(params, cfg, scheduler=scheduler)
        sess.prefill(prompt, gen_len=12)
        _, info = sess.run_compiled()
        steps[name] = info["steps"]
    assert steps["par"] <= steps["seq"]


def test_engine_lane_per_scheduler(small):
    """Requests with different schedulers are lane-partitioned; legacy
    parallel settings share a lane with the equivalent scheduler."""
    from repro.serving.engine import ServingEngine
    cfg, params, _ = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24,
                           strategy=NoCache())
    rng = np.random.default_rng(3)
    par_settings = DecodeSettings(parallel_threshold=0.05, max_parallel=2)
    par_sched = ParallelThresholdScheduler(threshold=0.05, max_parallel=2)
    for i in range(6):
        prompt = rng.integers(0, cfg.vocab_size - 1, 6).astype(np.int32)
        if i % 3 == 0:
            engine.submit(prompt, gen_len=4)
        elif i % 3 == 1:
            engine.submit(prompt, gen_len=4, settings=par_settings)
        else:
            engine.submit(prompt, gen_len=4, scheduler=par_sched)
    stats = engine.run()
    assert stats.requests_done == 6
    # TWO lanes only: the legacy parallel knobs are normalized out of
    # the lane key once resolved, so the knob form and the explicit
    # ParallelThresholdScheduler share one compiled executable
    assert len(engine._sessions) == 2
    assert {lane[2] for lane in engine._sessions} == {
        ConfidenceScheduler(), par_sched}
    for req in engine.done:
        assert (req.output != cfg.mask_id).all()


def test_engine_request_knobs_beat_engine_scheduler(small):
    """A request's legacy parallel knobs are a per-request override and
    must win over the ENGINE-level default scheduler."""
    from repro.serving.engine import ServingEngine
    cfg, params, _ = small
    engine = ServingEngine(cfg, params, max_batch=2, canvas_len=24,
                           strategy=NoCache(),
                           scheduler=ConfidenceScheduler())
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size - 1, 6).astype(np.int32)
    engine.submit(prompt, gen_len=4,
                  settings=DecodeSettings(parallel_threshold=0.3,
                                          max_parallel=4))
    engine.submit(prompt, gen_len=4)
    engine.run()
    assert {lane[2] for lane in engine._sessions} == {
        ConfidenceScheduler(),
        ParallelThresholdScheduler(threshold=0.3, max_parallel=4)}


def test_engine_request_settings_win_wholesale(small):
    """Explicit request settings with parallel_threshold=0.0 mean
    SEQUENTIAL even when the engine default scheduler is parallel."""
    from repro.serving.engine import ServingEngine
    cfg, params, _ = small
    engine = ServingEngine(
        cfg, params, max_batch=2, canvas_len=24, strategy=NoCache(),
        scheduler=ParallelThresholdScheduler(threshold=0.3,
                                             max_parallel=4))
    prompt = np.arange(6, dtype=np.int32) % (cfg.vocab_size - 1)
    engine.submit(prompt, gen_len=4, settings=DecodeSettings())
    engine.run()
    assert {lane[2] for lane in engine._sessions} == {
        ConfidenceScheduler()}


def test_finished_session_runs_zero_steps_both_modes(small):
    """run() and run_compiled() agree on an already-finished session:
    zero steps, no refresh-cadence drift from no-commit forwards."""
    cfg, params, prompt = small
    sess = DecodeSession(params, cfg)
    sess.prefill(prompt, gen_len=4)
    sess.run()
    for runner in ("run", "run_compiled"):
        toks, info = getattr(sess, runner)()
        assert info["steps"] == 0
        assert int((np.asarray(toks) == cfg.mask_id).sum()) == 0

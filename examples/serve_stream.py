#!/usr/bin/env python
"""Streaming client demo against the online serving front-end
(DESIGN.md §8).

Starts an in-process ``AsyncFrontend`` over a reduced untrained model
(real asyncio HTTP server on an ephemeral localhost port), then runs
three concurrent clients against it:

  * two *interactive* clients with a tight TTFT SLO — watch their
    per-token ndjson events arrive incrementally, not at the end;
  * one *impatient* client that disconnects after the first token
    batch — the server notices the dropped connection and cancels the
    request on the engine, releasing its pages mid-decode.

Against a real server started separately
(``python -m repro.launch.serve --serve --pool-pages 40 --page-size 4``)
point ``stream_request`` at that port instead.

  PYTHONPATH=src python examples/serve_stream.py
"""
import asyncio
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.strategy import SPACache
from repro.models import transformer
from repro.serving.engine import ServingEngine
from repro.serving.frontend import AsyncFrontend, fetch_stats, \
    stream_request
from repro.serving.slo import SLOPolicy

CANVAS, PAGE = 32, 4


def build_engine():
    cfg = reduced(get_arch("internlm2-1.8b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServingEngine(
        cfg, params, max_batch=2, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3,
                          refresh_interval=1),
        pool_pages=2 * (CANVAS // PAGE) + 2, page_size=PAGE,
        prefix_cache=True, slo_policy=SLOPolicy())


async def interactive_client(name, host, port, prompt, gen_len):
    t0 = time.time()
    n = 0
    async for ev in stream_request(host, port, prompt, gen_len,
                                   slo={"ttft": 30.0, "deadline": 120.0}):
        dt = (time.time() - t0) * 1e3
        if ev["kind"] == "token":
            if n == 0:
                print(f"[{name}] first token after {dt:.0f}ms")
            n += len(ev["tokens"])
            print(f"[{name}] +{dt:6.0f}ms step {ev['step']:3d} "
                  f"tokens={ev['tokens']}")
        else:
            print(f"[{name}] {ev['kind']} — {n} tokens streamed")


async def impatient_client(name, host, port, prompt):
    """Reads one token batch, then hangs up mid-stream."""
    agen = stream_request(host, port, prompt, 16)
    async for ev in agen:
        if ev["kind"] == "token":
            print(f"[{name}] got {ev['tokens']} — hanging up")
            break
    await agen.aclose()      # closes the socket; server cancels


async def main():
    cfg, engine = build_engine()
    front = AsyncFrontend(engine, max_steps=4096)
    await front.start(serve_http=True)
    print(f"front-end on http://{front.host}:{front.port}\n")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1, 8).astype(np.int32)
               for _ in range(3)]
    await asyncio.gather(
        interactive_client("alice", front.host, front.port,
                           prompts[0], 8),
        interactive_client("bob", front.host, front.port,
                           prompts[1], 8),
        impatient_client("carol", front.host, front.port, prompts[2]),
    )
    # the server notices carol's dropped socket on its next event
    # write, and the engine processes the cancel at its next step —
    # poll until the abort lands
    for _ in range(100):
        if engine.stats.requests_canceled:
            break
        await asyncio.sleep(0.2)
    stats = await fetch_stats(front.host, front.port)
    await front.stop()
    print(f"\nserver stats: {stats['requests_done']} done, "
          f"{stats['requests_canceled']} canceled, "
          f"TTFT p95 {stats['ttft_p95'] * 1e3:.0f}ms, "
          f"TPOT p50 {stats['tpot_p50'] * 1e3:.0f}ms")
    assert engine.pool.used == engine.prefix.held_pages, \
        "cancelled request leaked pages"
    print("page accounting clean after cancel — no leaks")


if __name__ == "__main__":
    asyncio.run(main())

#!/usr/bin/env python
"""End-to-end training driver: train a masked-diffusion LM of a chosen
architecture/size for a few hundred steps, with checkpointing and eval
generations.

  PYTHONPATH=src python examples/train_dlm.py --arch llada-8b \
      --d-model 256 --layers 8 --steps 300 --ckpt /tmp/dlm.npz

The default (~10M params) trains in minutes on CPU; pass bigger dims on
real hardware. ``--arch`` accepts any of the 12 registered architectures
(the reduced same-family variant is scaled to the requested dims).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.data.synthetic import token_batches
from repro.dlm import decoding
from repro.models import transformer
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--d-model", type=int, default=192)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", default="")
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch),
                  n_layers=args.layers, d_model=args.d_model,
                  n_heads=max(4, args.d_model // 32),
                  n_kv_heads=max(2, args.d_model // 64),
                  head_dim=32, d_ff=4 * args.d_model,
                  vocab_size=args.vocab)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params~{cfg.param_count():,}")

    trainer = Trainer(cfg, AdamWConfig(
        lr=args.lr, warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps))
    if args.resume:
        params, meta = checkpoint.load_checkpoint(args.resume)
        trainer.params = params
        from repro.training.optimizer import init_opt_state
        trainer.opt_state = init_opt_state(params)
        print(f"resumed from {args.resume} (step {meta.get('step')})")
    else:
        trainer.init(jax.random.PRNGKey(0))

    data = token_batches(cfg, batch_size=args.batch, seq_len=args.seq,
                         seed=0)
    t0 = time.time()
    hist = trainer.fit(data, n_steps=args.steps,
                       rng=jax.random.PRNGKey(1), log_every=20)
    dt = time.time() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({tok_s:,.0f} tokens/s); loss "
          f"{np.mean(hist['loss'][:5]):.3f} -> "
          f"{np.mean(hist['loss'][-5:]):.3f}")

    if args.ckpt:
        checkpoint.save_checkpoint(args.ckpt, trainer.params,
                                   {"step": args.steps,
                                    "arch": cfg.name})
        print(f"checkpoint written to {args.ckpt}")

    if not cfg.is_encoder_only and cfg.frontend is None:
        prompt = jnp.asarray(next(token_batches(cfg, 2, 16, seed=7))
                             ["tokens"])
        toks, info = decoding.decode(trainer.params, cfg, prompt,
                                     gen_len=24)
        print(f"sample generation ({info['steps']} refinement steps): "
              f"{np.asarray(toks)[0, 16:28]}")


if __name__ == "__main__":
    main()

#!/usr/bin/env python
"""Quickstart: train a tiny diffusion LM on synthetic text, then decode
the same prompt with (a) vanilla full recomputation and (b) SPA-Cache,
printing the speedup and token agreement.

The caching policy is a call-time ``CacheStrategy`` and the commit
policy a call-time ``UnmaskScheduler`` — the ModelConfig never changes
between runs.  Both decodes use ``DecodeSession.run_compiled()``: the
whole unmasking loop is ONE ``lax.while_loop`` on device (no per-step
Python dispatch).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.strategy import NoCache, SPACache
from repro.data.synthetic import token_batches
from repro.dlm.scheduler import ParallelThresholdScheduler
from repro.dlm.session import DecodeSession
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def main():
    cfg = reduced(get_arch("llada-8b"), n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=4, head_dim=32, d_ff=512,
                  vocab_size=512)
    print(f"model: {cfg.name}-reduced  params ~{cfg.param_count():,}")

    trainer = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=10,
                                       total_steps=120)).init(
        jax.random.PRNGKey(0))
    data = token_batches(cfg, batch_size=8, seq_len=64, seed=0)
    print("training 100 steps on synthetic Markov text ...")
    trainer.fit(data, n_steps=100, rng=jax.random.PRNGKey(1),
                log_every=25)
    params = trainer.params

    prompt = jnp.asarray(next(token_batches(cfg, 2, 16, seed=9))
                         ["tokens"])
    gen_len = 32

    vanilla = NoCache()
    spa = SPACache(rank=16, schedule="adaptive", rho_peak=0.25,
                   rho_first=0.03, rho_last=0.13)
    # commit up to 4 confident tokens per refinement step (Fast-dLLM)
    scheduler = ParallelThresholdScheduler(threshold=0.3, max_parallel=4)

    print("\ndecoding with vanilla full recomputation ...")
    t0 = time.time()
    sess = DecodeSession(params, cfg, strategy=vanilla,
                         scheduler=scheduler)
    sess.prefill(prompt, gen_len)
    toks_v, info_v = sess.run_compiled()
    t_v = time.time() - t0
    print(f"  {info_v['steps']} steps, {t_v:.2f}s")

    print("decoding with SPA-Cache (singular proxy r=16, adaptive rho) ...")
    t0 = time.time()
    sess = DecodeSession(params, cfg, strategy=spa, scheduler=scheduler)
    sess.prefill(prompt, gen_len)
    toks_s, info_s = sess.run_compiled()
    t_s = time.time() - t0
    print(f"  {info_s['steps']} steps, {t_s:.2f}s")

    agree = (np.asarray(toks_v) == np.asarray(toks_s)).mean()
    print(f"\nwall-clock speedup (incl. compile): {t_v / t_s:.2f}x")
    print(f"token agreement vs vanilla: {agree:.1%}")
    print(f"generated (row 0): {np.asarray(toks_s)[0, 16:16+12]} ...")


if __name__ == "__main__":
    main()

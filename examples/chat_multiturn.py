#!/usr/bin/env python
"""Multi-turn chat on the paged serving engine with the shared-prefix
radix cache (DESIGN.md §6).

Every turn resubmits the GROWING transcript (system prompt + all prior
turns + the new user message) as one request.  With the prefix cache
on, the radix index recognizes the transcript's page-aligned prefix
from the previous turn and attaches those pages read-only: turn 1 is a
cold prefill that publishes its pages; turn 2 partial-hits them and
prefills only its new suffix; resubmitting an identical transcript
(regenerate) is a FULL hit that runs no prefill forward at all.

Two details make the turns line up in the index:

  * every submit reserves the full canvas (``row_len=CANVAS``) so the
    layout half of the match key is identical across turns, and
  * partial hits publish their own suffix pages, deepening the trie so
    the NEXT turn matches the whole previous transcript, not just the
    system prompt.

  PYTHONPATH=src python examples/chat_multiturn.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.strategy import SPACache
from repro.models import transformer
from repro.serving.engine import ServingEngine

PAGE = 8
CANVAS = 64
TURNS = 4
GEN = 8


def main():
    cfg = reduced(get_arch("llada-8b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, max_batch=2, canvas_len=CANVAS,
        strategy=SPACache(rank=16, schedule="uniform", rho_peak=0.3),
        pool_pages=4 * (CANVAS // PAGE) + 1, page_size=PAGE,
        prefix_cache=True)

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size - 1, 14).astype(np.int32)
    transcript = system
    print(f"system prompt: {len(system)} tokens; canvas {CANVAS}, "
          f"page {PAGE}\n")
    for turn in range(1, TURNS + 1):
        user = rng.integers(0, cfg.vocab_size - 1, 4).astype(np.int32)
        transcript = np.concatenate([transcript, user])
        hits0 = eng.stats.prefix_hits
        saved0 = eng.stats.prefix_tokens_saved
        uid = eng.submit(transcript, gen_len=GEN, row_len=CANVAS)
        eng.run()
        reply = [r for r in eng.done if r.uid == uid][0].output
        transcript = np.concatenate([transcript, reply])
        hit = eng.stats.prefix_hits - hits0
        print(f"turn {turn}: transcript {len(transcript) - GEN:3d} tokens"
              f" -> {'hit' if hit else 'cold'}, "
              f"{eng.stats.prefix_tokens_saved - saved0} prefill rows "
              f"reused, reply {reply[:6]}...")

    # a regenerate of the final turn is a FULL hit: zero prefill forward
    full0 = eng.stats.prefix_full_hits
    uid = eng.submit(transcript[: len(transcript) - GEN], gen_len=GEN,
                     row_len=CANVAS)
    eng.run()
    assert eng.stats.prefix_full_hits == full0 + 1
    print(f"\nregenerate: full hit (prefill skipped entirely); "
          f"index stats: {eng.prefix.hits} hits / "
          f"{eng.prefix.misses} misses, "
          f"{eng.prefix.held_pages} pages held, "
          f"{eng.stats.prefix_tokens_saved} total prefill rows saved")
    return 0


if __name__ == "__main__":
    sys.exit(main())

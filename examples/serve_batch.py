#!/usr/bin/env python
"""Batched serving demo: the ServingEngine answers a queue of requests
with SPA-Cache sparse refinement, and reports throughput vs the vanilla
engine on the same queue.

Half the requests decode with a Fast-dLLM parallel-commit scheduler and
half with a semi-AR block scheduler — per-request ``UnmaskScheduler``s
are lane-partitioned by the engine exactly like per-request settings
(one compiled step per (settings, strategy, scheduler) lane).  A third
pass serves the same queue through the PAGED runtime (DESIGN.md §5): a
page pool a fraction of the dense aggregate, admission control and
priority preemption instead of per-lane slabs.

  PYTHONPATH=src python examples/serve_batch.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.strategy import NoCache, SPACache
from repro.data.synthetic import token_batches
from repro.dlm.decoding import DecodeSettings
from repro.dlm.scheduler import BlockScheduler, ParallelThresholdScheduler
from repro.serving.engine import ServingEngine
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def main():
    cfg = reduced(get_arch("dream-7b"), n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512,
                  vocab_size=512)
    trainer = Trainer(cfg, AdamWConfig(lr=3e-3, total_steps=80)).init(
        jax.random.PRNGKey(0))
    data = token_batches(cfg, batch_size=8, seq_len=64, seed=0)
    print("training a small model to serve ...")
    trainer.fit(data, n_steps=60, rng=jax.random.PRNGKey(1),
                log_every=0)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size - 1,
                            rng.integers(8, 20)).astype(np.int32)
               for _ in range(8)]

    schedulers = [ParallelThresholdScheduler(threshold=0.3,
                                             max_parallel=2),
                  BlockScheduler(block_len=8, threshold=0.3,
                                 max_parallel=2)]
    results = {}
    # the paged engine serves the SAME queue from a pooled page arena
    # about a third of the dense aggregate (DESIGN.md §5): heterogeneous
    # requests only allocate the pages covering their own span, and
    # admission control queues what doesn't fit
    for name, strategy, pool_pages in (
        ("vanilla", NoCache(), 0),
        ("spa-cache", SPACache(rank=16, schedule="adaptive",
                               rho_peak=0.25, rho_first=0.03,
                               rho_last=0.13), 0),
        ("spa-paged", SPACache(rank=16, schedule="adaptive",
                               rho_peak=0.25, rho_first=0.03,
                               rho_last=0.13), 17),
    ):
        engine = ServingEngine(
            cfg, trainer.params, max_batch=4, canvas_len=48,
            strategy=strategy, settings=DecodeSettings(),
            pool_pages=pool_pages, page_size=8)
        for i, p in enumerate(prompts):
            engine.submit(p, gen_len=16, scheduler=schedulers[i % 2],
                          priority=i % 2)
        stats = engine.run()
        results[name] = (stats, engine._wall)
        print(f"[{name:9s}] {stats.requests_done} requests, "
              f"{stats.tokens_committed} tokens in {engine._wall:.2f}s "
              f"({stats.tps(engine._wall):.1f} tok/s, "
              f"{stats.steps} refinement steps, {stats.swaps} swaps)")
        if pool_pages:
            pct = stats.percentiles()
            print(f"            pool {pool_pages} x 8 rows: peak util "
                  f"{stats.peak_pool_util:.0%}, steady "
                  f"{stats.steady_pool_util:.0%}, "
                  f"{stats.preemptions} preemptions, "
                  f"{stats.admission_stalls} stalls | e2e p95 "
                  f"{pct['e2e_p95']:.2f}s")

    sp = results["spa-cache"][0].tps(results["spa-cache"][1]) / \
        max(results["vanilla"][0].tps(results["vanilla"][1]), 1e-9)
    print(f"\nSPA-Cache serving speedup: {sp:.2f}x")


if __name__ == "__main__":
    main()

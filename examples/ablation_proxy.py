#!/usr/bin/env python
"""Identifier ablation walkthrough (paper Table 1 + §3.2 theory): shows
how well each identifier's drift scores predict true FFN-output drift,
then times decoding with each.

  PYTHONPATH=src python examples/ablation_proxy.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common, table1_identifiers
from repro.core.svd_proxy import build_proxy, cosine_similarity
from repro.models import common as mcommon, transformer


def score_fidelity():
    """Correlate identifier drift scores with true block-output drift."""
    cfg = common.bench_model(n_layers=2, d_model=128)
    params = common.trained_bench_model(cfg, steps=20)
    bp = jax.tree.map(lambda a: a[0], params["blocks"]["attn"])
    rng = np.random.default_rng(0)
    h0 = jnp.asarray(rng.standard_normal((1, 128, cfg.d_model))
                     .astype(np.float32))
    drift = jnp.asarray((rng.standard_normal((1, 128, cfg.d_model))
                         * rng.uniform(0, 0.5, (1, 128, 1)))
                        .astype(np.float32))
    h1 = h0 + drift

    out0, _, _ = transformer.apply_block_dense(cfg, "attn", bp, h0)
    out1, _, _ = transformer.apply_block_dense(cfg, "attn", bp, h1)
    true_drift = 1 - np.asarray(cosine_similarity(out0, out1))[0]

    x0 = mcommon.rms_norm(h0, bp["norm1"], cfg.norm_eps)
    x1 = mcommon.rms_norm(h1, bp["norm1"], cfg.norm_eps)
    proxy16, bound = build_proxy(np.asarray(bp["wv"], np.float32), 16)
    candidates = {
        "value": (x0 @ bp["wv"], x1 @ bp["wv"]),
        "singular_r16": (x0 @ jnp.asarray(proxy16),
                         x1 @ jnp.asarray(proxy16)),
        "query": (x0 @ bp["wq"], x1 @ bp["wq"]),
        "key": (x0 @ bp["wk"], x1 @ bp["wk"]),
        "attn_in": (x0, x1),
    }
    print("identifier score vs TRUE block-output drift "
          f"(Thm 3.4 bound for r=16: {bound:.4f}):")
    for name, (p0, p1) in candidates.items():
        pred = 1 - np.asarray(cosine_similarity(p0, p1))[0]
        corr = np.corrcoef(true_drift, pred)[0, 1]
        print(f"  {name:14s} spearman-ish corr = {corr:.3f}")


if __name__ == "__main__":
    score_fidelity()
    print("\nfull Table-1 timing comparison:")
    table1_identifiers.run(quick=True)
